//! Reverse-mode gradients through the native backbone — the training half
//! of the paper's "fully parallelizable" claim (Section 3, Appendix B).
//!
//! [`forward`] runs the same parallel pass as inference — GEMMs through
//! the tiled [`Dense`] kernel, gates in log space, the chunked log-space
//! scan — but records every activation the backward pass needs on a
//! [`Tape`].  [`backward`] then walks the tape in reverse:
//!
//! * the scan `v_t = a_t ⊙ v_{t-1} + b_t` has the clean reverse recurrence
//!   `dL/dv_{t-1} = a_t ⊙ dL/dv_t`, so the scan VJP is a per-channel
//!   time-reversed sweep over the cached state sequence (`da_t = ḡ_t ⊙
//!   v_{t-1}`, `db_t = ḡ_t`, carry `ḡ_{t-1} += a_t ⊙ ḡ_t`) — parallel
//!   over the `B×D` channel grid exactly like the forward scan;
//! * gate pre-activations backprop through the softplus / `log g`
//!   algebra's real-space equivalents (`a = σ(-k)`, `b = σ(k) g(pre)` for
//!   minGRU; the normalized `f'/i'` pair for minLSTM);
//! * Dense/RMSNorm/Conv4/GELU/embedding each get a hand-written VJP with
//!   the same fixed task granularity as the forward kernels, so gradients
//!   are bit-for-bit identical across thread counts.
//!
//! Mixer-specific math lives behind [`super::mixer::Mixer`]'s
//! `forward_tape`/`backward` hooks — this module owns the backbone
//! plumbing (norms, conv, MLP, dropout, residuals, input/positional
//! layers) plus the shared primitive VJPs the mixers call back into
//! ([`dense_bwd`], [`scan_gate_bwd`]).
//!
//! Gradients accumulate into a [`NativeModel`]-shaped container
//! ([`NativeModel::zeros_like`]); `backend::native::adam` consumes them
//! leaf-by-leaf.  Correctness is pinned by finite-difference checks in
//! `rust/tests/train_props.rs` (every leaf, every mixer kind, conv/MLP
//! on and off).

use anyhow::{bail, Result};

use crate::tensor::{Tensor, TensorData};
use crate::util::rng::splitmix64;
use crate::util::threads::{self, SlicePtr, ThreadPool};

use super::linalg::{self, g, g_grad, gelu, gelu_grad, sigmoid, silu,
                    silu_grad, softplus, Dense};
use super::mingru::{GATE_CHUNK, H0_VALUE};
use super::mixer::{Mixer, MixerTape};
use super::model::{InputLayer, NativeModel};
use super::scan;

/// Rows per parallel task in the backward GEMMs (mirrors the forward
/// kernels' fixed blocking so results are thread-count invariant).
const ROW_BLOCK: usize = 32;
/// Channels per parallel task of the reverse scan (the forward scan's
/// [`scan::D_BLOCK`]).
const D_BLOCK: usize = scan::D_BLOCK;
/// Below this many multiply-adds a backward GEMM runs inline.
const PAR_MIN_MACS: usize = 1 << 15;
/// Below this many elements an elementwise map / scan runs inline.
const PAR_MIN_MAP: usize = 1 << 14;

// ---------------------------------------------------------------------------
// tape
// ---------------------------------------------------------------------------

/// Per-block cached activations of one training forward pass.
pub struct BlockTape {
    /// Residual stream entering the block (RMSNorm 1 input), `(B·T, d)`.
    pub h_in: Vec<f32>,
    /// RMSNorm 1 output, `(B·T, d)`.
    pub u1: Vec<f32>,
    /// Pre-SiLU conv activations, `(B·T, d)` (conv blocks only).
    pub conv_pre: Option<Vec<f32>>,
    /// Mixer input — conv output when conv is present, else `u1`.
    pub mixer_in: Vec<f32>,
    /// Mixer-kind-specific activations ([`Mixer::forward_tape`]).
    pub mixer: MixerTape,
    /// Residual after the mixer (RMSNorm 2 input; MLP blocks only).
    pub h_mid: Option<Vec<f32>>,
    /// RMSNorm 2 output (MLP blocks only).
    pub u2: Option<Vec<f32>>,
    /// MLP hidden pre-activations (before GELU), `(B·T, mult·d)`.
    pub mlp_pre: Option<Vec<f32>>,
    /// Inverted-dropout multipliers applied to the mixer residual branch
    /// (`None` when `drop_rate == 0` — that path is bit-identical to the
    /// pre-dropout forward).
    pub drop_mixer: Option<Vec<f32>>,
    /// Inverted-dropout multipliers on the MLP residual branch.
    pub drop_mlp: Option<Vec<f32>>,
}

/// Everything [`backward`] needs from one forward pass.
pub struct Tape {
    pub batch: usize,
    pub t: usize,
    pub blocks: Vec<BlockTape>,
    /// Residual stream entering the final RMSNorm, `(B·T, d)`.
    pub h_fin: Vec<f32>,
    /// Final RMSNorm output (head input), `(B·T, d)`.
    pub u_f: Vec<f32>,
    /// All-position logits, `(B, T, vocab_out)`.
    pub logits: Vec<f32>,
}

// ---------------------------------------------------------------------------
// forward (recording)
// ---------------------------------------------------------------------------

/// Elementwise map across the pool in fixed chunks.
fn map_pool(pool: &ThreadPool, src: &[f32], dst: &mut Vec<f32>,
            f: impl Fn(f32) -> f32 + Sync) {
    linalg::reuse(dst, src.len());
    if src.len() < PAR_MIN_MAP || pool.active() == 1 {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f(s);
        }
        return;
    }
    let dp = SlicePtr::new(dst.as_mut_slice());
    pool.run_chunks(src.len(), GATE_CHUNK, |s, e| {
        let dv = unsafe { dp.slice(s, e - s) };
        for (i, d) in dv.iter_mut().enumerate() {
            *d = f(src[s + i]);
        }
    });
}

/// Inverted-dropout multiplier for element `idx` of dropout stream
/// `stream`: 0 with probability `rate`, else `1/(1-rate)`.  Streams
/// mirror `backbone.py`'s key folding — `2·layer` for the mixer residual
/// branch, `2·layer + 1` for the MLP branch.  Counter-based (SplitMix64
/// of seed/stream/index), so any element's multiplier is computable
/// independently of every other: masks are bit-identical across thread
/// counts, and tests can mirror them exactly.
pub fn drop_multiplier(seed: i32, stream: u64, idx: u64, rate: f32) -> f32 {
    let mut key = (seed as u32 as u64)
        ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
    key = key.wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let u = (splitmix64(&mut key) >> 11) as f64
        * (1.0 / (1u64 << 53) as f64);
    if u < rate as f64 {
        0.0
    } else {
        1.0 / (1.0 - rate)
    }
}

/// Generate one residual branch's dropout mask and apply it to `v` in
/// place (fixed [`GATE_CHUNK`] task granularity).  `None` when
/// `rate <= 0`: zero rate never touches `v`, keeping that path
/// bit-identical to the no-dropout forward.
fn drop_branch(pool: &ThreadPool, v: &mut [f32], rate: f32, seed: i32,
               stream: u64) -> Option<Vec<f32>> {
    if rate <= 0.0 {
        return None;
    }
    let n = v.len();
    let mut mask = vec![0.0f32; n];
    let apply = |mv: &mut [f32], vv: &mut [f32], s: usize| {
        for (i, (m, x)) in mv.iter_mut().zip(vv.iter_mut()).enumerate() {
            *m = drop_multiplier(seed, stream, (s + i) as u64, rate);
            *x *= *m;
        }
    };
    if n < PAR_MIN_MAP || pool.active() == 1 {
        apply(mask.as_mut_slice(), v, 0);
    } else {
        let mp = SlicePtr::new(mask.as_mut_slice());
        let vp = SlicePtr::new(v);
        pool.run_chunks(n, GATE_CHUNK, |s, e| {
            let mv = unsafe { mp.slice(s, e - s) };
            let vv = unsafe { vp.slice(s, e - s) };
            apply(mv, vv, s);
        });
    }
    Some(mask)
}

/// `dst = a ⊙ b` across the pool in fixed chunks (dropout backward).
fn mul_pool(pool: &ThreadPool, a: &[f32], b: &[f32], dst: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), b.len());
    linalg::reuse(dst, a.len());
    if a.len() < PAR_MIN_MAP || pool.active() == 1 {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x * y;
        }
        return;
    }
    let dp = SlicePtr::new(dst.as_mut_slice());
    pool.run_chunks(a.len(), GATE_CHUNK, |s, e| {
        let dv = unsafe { dp.slice(s, e - s) };
        for (i, d) in dv.iter_mut().enumerate() {
            *d = a[s + i] * b[s + i];
        }
    });
}

/// Training forward pass without dropout — see [`forward_train`].
pub fn forward(model: &NativeModel, x: &Tensor) -> Result<Tape> {
    forward_train(model, x, 0.0, 0)
}

/// Training forward pass: identical math to [`NativeModel::forward`]
/// (parallel gates + chunked log-space scan), recording activations.
/// When `drop_rate > 0`, inverted dropout is applied to the two residual
/// branches (mixer output, MLP output — `backbone.py`'s placement) with
/// masks keyed on `drop_seed`; `drop_rate == 0` leaves every value
/// untouched, bit-identical to the pre-dropout path.
pub fn forward_train(model: &NativeModel, x: &Tensor, drop_rate: f32,
                     drop_seed: i32) -> Result<Tape> {
    let (batch, t) = match (x.dims.len(), &x.data) {
        (2, TensorData::I32(_)) => (x.dims[0], x.dims[1]),
        (3, TensorData::F32(_)) => (x.dims[0], x.dims[1]),
        _ => bail!("train forward expects (B, T) i32 or (B, T, F) f32, \
                    got {:?} {}", x.dims, x.dtype_name()),
    };
    if t == 0 {
        bail!("empty sequence");
    }
    let pool = threads::global();
    let rows = batch * t;
    let d = model.d_model;
    let mut h = Vec::new();
    model.embed_rows_into(x, rows, &mut h)?;
    // learned absolute positions (transformer backbones): row `min(t,
    // L-1)` added to every lane, matching the clamped decode lookup
    if let Some(pe) = &model.pos {
        for bi in 0..batch {
            for ti in 0..t {
                let row = ti.min(pe.vocab - 1);
                let prow = &pe.w[row * d..(row + 1) * d];
                let hrow = &mut h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                for i in 0..d {
                    hrow[i] += prow[i];
                }
            }
        }
    }

    let mut blocks = Vec::with_capacity(model.blocks.len());
    for (li, blk) in model.blocks.iter().enumerate() {
        let h_in = h.clone();
        let mut u1 = Vec::new();
        linalg::rmsnorm_pool_into(pool, &h, &blk.ln1, rows, d, &mut u1);
        let (conv_pre, mixer_in) = match &blk.conv {
            Some(conv) => {
                let mut pre = Vec::new();
                conv.parallel_pre_pool_into(pool, &u1, batch, t, &mut pre);
                let mut out = Vec::new();
                map_pool(pool, &pre, &mut out, silu);
                (Some(pre), out)
            }
            None => (None, u1.clone()),
        };
        let (mixer_tape, mut y) =
            blk.mixer.m().forward_tape(pool, &mixer_in, batch, t)?;
        let drop_mixer = drop_branch(pool, &mut y, drop_rate, drop_seed,
                                     2 * li as u64);
        linalg::add_assign(&mut h, &y);

        let (h_mid, u2, mlp_pre, drop_mlp) = match (&blk.ln2, &blk.mlp) {
            (Some(ln2), Some(mlp)) => {
                let h_mid = h.clone();
                let mut u2 = Vec::new();
                linalg::rmsnorm_pool_into(pool, &h, ln2, rows, d, &mut u2);
                let mut mlp_pre = Vec::new();
                mlp.up.apply_pool_into(pool, &u2, rows, &mut mlp_pre);
                let mut act = Vec::new();
                map_pool(pool, &mlp_pre, &mut act, gelu);
                let mut z = Vec::new();
                mlp.down.apply_pool_into(pool, &act, rows, &mut z);
                let drop_mlp = drop_branch(pool, &mut z, drop_rate,
                                           drop_seed, 2 * li as u64 + 1);
                linalg::add_assign(&mut h, &z);
                (Some(h_mid), Some(u2), Some(mlp_pre), drop_mlp)
            }
            _ => (None, None, None, None),
        };
        blocks.push(BlockTape { h_in, u1, conv_pre, mixer_in,
                                mixer: mixer_tape, h_mid, u2, mlp_pre,
                                drop_mixer, drop_mlp });
    }
    let h_fin = h.clone();
    let mut u_f = Vec::new();
    linalg::rmsnorm_pool_into(pool, &h, &model.ln_f, rows, d, &mut u_f);
    let mut logits = Vec::new();
    model.head.apply_pool_into(pool, &u_f, rows, &mut logits);
    Ok(Tape { batch, t, blocks, h_fin, u_f, logits })
}

// ---------------------------------------------------------------------------
// primitive VJPs
// ---------------------------------------------------------------------------

/// Backward of `y = x @ w + b`.  Accumulates `gw`/`gb`; when `dx` is given
/// it receives `dy @ wᵀ` (set or `+=` per `accumulate`).  Work fans out in
/// fixed row / input-dim blocks, so gradients are thread-count invariant.
/// Shared with the mixer `backward` implementations.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_bwd(pool: &ThreadPool, dense: &Dense, x: &[f32],
                        dy: &[f32], rows: usize,
                        dx: Option<(&mut Vec<f32>, bool)>,
                        gw: &mut [f32], gb: &mut [f32]) {
    let (d_in, d_out) = (dense.d_in, dense.d_out);
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(gw.len(), d_in * d_out);
    debug_assert_eq!(gb.len(), d_out);
    let inline = rows * d_in * d_out < PAR_MIN_MACS || pool.active() == 1;

    if let Some((dx, accumulate)) = dx {
        linalg::reuse(dx, rows * d_in);
        let dx_rows = |dxb: &mut [f32], r0: usize, r1: usize| {
            for r in r0..r1 {
                let dyr = &dy[r * d_out..(r + 1) * d_out];
                let dxr = &mut dxb[(r - r0) * d_in..(r - r0 + 1) * d_in];
                for i in 0..d_in {
                    let wrow = &dense.w[i * d_out..(i + 1) * d_out];
                    let mut acc = 0.0f32;
                    for j in 0..d_out {
                        acc += dyr[j] * wrow[j];
                    }
                    if accumulate {
                        dxr[i] += acc;
                    } else {
                        dxr[i] = acc;
                    }
                }
            }
        };
        if inline {
            dx_rows(dx.as_mut_slice(), 0, rows);
        } else {
            let dxp = SlicePtr::new(dx.as_mut_slice());
            pool.run(rows.div_ceil(ROW_BLOCK), |bi| {
                let r0 = bi * ROW_BLOCK;
                let r1 = (r0 + ROW_BLOCK).min(rows);
                let dxb = unsafe { dxp.slice(r0 * d_in, (r1 - r0) * d_in) };
                dx_rows(dxb, r0, r1);
            });
        }
    }

    // gw[i, j] += Σ_r x[r, i] · dy[r, j]; each task owns gw rows [i0, i1)
    // exclusively, summing rows in ascending order (deterministic).
    let gw_rows = |gwb: &mut [f32], i0: usize, i1: usize| {
        for r in 0..rows {
            let dyr = &dy[r * d_out..(r + 1) * d_out];
            for i in i0..i1 {
                let xv = x[r * d_in + i];
                if xv != 0.0 {
                    let grow = &mut gwb[(i - i0) * d_out
                                        ..(i - i0 + 1) * d_out];
                    for j in 0..d_out {
                        grow[j] += xv * dyr[j];
                    }
                }
            }
        }
    };
    if inline {
        gw_rows(gw, 0, d_in);
    } else {
        let gwp = SlicePtr::new(gw);
        pool.run(d_in.div_ceil(ROW_BLOCK), |bi| {
            let i0 = bi * ROW_BLOCK;
            let i1 = (i0 + ROW_BLOCK).min(d_in);
            let gwb = unsafe { gwp.slice(i0 * d_out, (i1 - i0) * d_out) };
            gw_rows(gwb, i0, i1);
        });
    }

    for r in 0..rows {
        let dyr = &dy[r * d_out..(r + 1) * d_out];
        for j in 0..d_out {
            gb[j] += dyr[j];
        }
    }
}

/// Backward of RMSNorm `y_i = x_i · inv · s_i`, `inv = (mean x² + ε)^-½`:
/// `dx = s ⊙ dy · inv − x · inv³/d · Σ_j dy_j s_j x_j`,
/// `ds_i += Σ_rows dy_i x_i inv`.
#[allow(clippy::too_many_arguments)]
fn rmsnorm_bwd(pool: &ThreadPool, x: &[f32], scale: &[f32], rows: usize,
               d: usize, dy: &[f32], dx: &mut Vec<f32>, gs: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(dy.len(), rows * d);
    debug_assert_eq!(scale.len(), d);
    debug_assert_eq!(gs.len(), d);
    linalg::reuse(dx, rows * d);
    let mut inv = vec![0.0f32; rows];
    let bwd_rows = |dxb: &mut [f32], invb: &mut [f32], r0: usize,
                    r1: usize| {
        for r in r0..r1 {
            let xr = &x[r * d..(r + 1) * d];
            let dyr = &dy[r * d..(r + 1) * d];
            let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let rinv = 1.0 / (ms + 1e-6).sqrt();
            invb[r - r0] = rinv;
            let mut dot = 0.0f32;
            for i in 0..d {
                dot += dyr[i] * scale[i] * xr[i];
            }
            let c = rinv * rinv * rinv * dot / d as f32;
            let dxr = &mut dxb[(r - r0) * d..(r - r0 + 1) * d];
            for i in 0..d {
                dxr[i] = dyr[i] * scale[i] * rinv - xr[i] * c;
            }
        }
    };
    if rows * d < PAR_MIN_MAP || pool.active() == 1 {
        bwd_rows(dx.as_mut_slice(), inv.as_mut_slice(), 0, rows);
    } else {
        let dxp = SlicePtr::new(dx.as_mut_slice());
        let ivp = SlicePtr::new(inv.as_mut_slice());
        pool.run(rows.div_ceil(ROW_BLOCK), |bi| {
            let r0 = bi * ROW_BLOCK;
            let r1 = (r0 + ROW_BLOCK).min(rows);
            let dxb = unsafe { dxp.slice(r0 * d, (r1 - r0) * d) };
            let ivb = unsafe { ivp.slice(r0, r1 - r0) };
            bwd_rows(dxb, ivb, r0, r1);
        });
    }
    // scale gradient: sequential row sweep, deterministic accumulation
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let rinv = inv[r];
        for i in 0..d {
            gs[i] += dyr[i] * xr[i] * rinv;
        }
    }
}

/// Backward of the depthwise causal conv + SiLU.  Channels are
/// independent, so the `D` axis splits into fixed blocks; each task owns
/// its channels' `dx` columns and `gw`/`gb` entries exclusively.
#[allow(clippy::too_many_arguments)]
fn conv4_bwd(pool: &ThreadPool, conv: &super::linalg::Conv4, x: &[f32],
             pre: &[f32], dy: &[f32], batch: usize, t: usize,
             dx: &mut Vec<f32>, gw: &mut [f32], gb: &mut [f32]) {
    let (d, kk) = (conv.d, conv.k);
    debug_assert_eq!(x.len(), batch * t * d);
    debug_assert_eq!(pre.len(), batch * t * d);
    debug_assert_eq!(dy.len(), batch * t * d);
    linalg::reuse(dx, batch * t * d);
    dx.iter_mut().for_each(|v| *v = 0.0);
    let blocks = d.div_ceil(D_BLOCK);
    let dxp = SlicePtr::new(dx.as_mut_slice());
    let gwp = SlicePtr::new(gw);
    let gbp = SlicePtr::new(gb);
    let task = |ci: usize| {
        let d0 = ci * D_BLOCK;
        let d1 = (d0 + D_BLOCK).min(d);
        for di in d0..d1 {
            let mut gwl = vec![0.0f32; kk];
            let mut gbl = 0.0f32;
            for bi in 0..batch {
                for ti in 0..t {
                    let off = (bi * t + ti) * d + di;
                    let dpre = dy[off] * silu_grad(pre[off]);
                    if dpre == 0.0 {
                        continue;
                    }
                    gbl += dpre;
                    for j in 0..kk {
                        let src = ti as isize + j as isize
                            - (kk as isize - 1);
                        if src >= 0 {
                            let xoff = (bi * t + src as usize) * d + di;
                            gwl[j] += dpre * x[xoff];
                            let dxs = unsafe { dxp.slice(xoff, 1) };
                            dxs[0] += conv.w[j * d + di] * dpre;
                        }
                    }
                }
            }
            for j in 0..kk {
                let gws = unsafe { gwp.slice(j * d + di, 1) };
                gws[0] += gwl[j];
            }
            let gbs = unsafe { gbp.slice(di, 1) };
            gbs[0] += gbl;
        }
    };
    if batch * t * d < PAR_MIN_MAP || pool.active() == 1 {
        for ci in 0..blocks {
            task(ci);
        }
    } else {
        pool.run(blocks, task);
    }
}

/// Scatter-add token-embedding gradients (clamped ids, like the lookup).
fn embed_bwd(ids: &[i32], dh: &[f32], vocab: usize, d: usize,
             gw: &mut [f32]) {
    debug_assert_eq!(dh.len(), ids.len() * d);
    for (r, &id) in ids.iter().enumerate() {
        let row = (id.max(0) as usize).min(vocab - 1);
        let grow = &mut gw[row * d..(row + 1) * d];
        let dhr = &dh[r * d..(r + 1) * d];
        for i in 0..d {
            grow[i] += dhr[i];
        }
    }
}

/// Reverse sweep through the scan + gate algebra of the minimal-RNN
/// mixers: consumes the hidden-state gradient `dh_seq` and writes
/// pre-activation gradients `dk`/`dpre` (and `df` for minLSTM, which
/// passes `f: Some(..)`).  Parallel over the `B×D` channel grid in fixed
/// blocks, sequential over time within a channel.  Called from the
/// [`Mixer::backward`] impls in `mixer.rs`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_gate_bwd(pool: &ThreadPool, k: &[f32], pre: &[f32],
                            f: Option<&[f32]>, h: &[f32], batch: usize,
                            t: usize, dh: usize, dh_seq: &[f32],
                            dk: &mut Vec<f32>, dpre: &mut Vec<f32>,
                            df: &mut Vec<f32>) {
    let is_lstm = f.is_some();
    let n = batch * t * dh;
    debug_assert_eq!(dh_seq.len(), n);
    linalg::reuse(dk, n);
    linalg::reuse(dpre, n);
    if is_lstm {
        linalg::reuse(df, n);
    }
    let blocks = dh.div_ceil(D_BLOCK);
    let dkp = SlicePtr::new(dk.as_mut_slice());
    let dpp = SlicePtr::new(dpre.as_mut_slice());
    let dfp = SlicePtr::new(df.as_mut_slice());
    let (kv, pv) = (k, pre);
    let fv = f;
    let hv = h;
    let task = |idx: usize| {
        let bi = idx / blocks;
        let d0 = (idx % blocks) * D_BLOCK;
        let d1 = (d0 + D_BLOCK).min(dh);
        let w = d1 - d0;
        let mut carry = [0.0f32; scan::D_BLOCK];
        for ti in (0..t).rev() {
            let off = (bi * t + ti) * dh + d0;
            let dks = unsafe { dkp.slice(off, w) };
            let dps = unsafe { dpp.slice(off, w) };
            for j in 0..w {
                let o = off + j;
                let g_tot = carry[j] + dh_seq[o];
                let hprev = if ti > 0 { hv[o - dh] } else { H0_VALUE };
                let da = g_tot * hprev;
                let db = g_tot;
                if is_lstm {
                    // f' = σ(-diff), i' = σ(diff),
                    // diff = softplus(-f) - softplus(-k)
                    let f = fv.unwrap();
                    let diff = softplus(-f[o]) - softplus(-kv[o]);
                    let fp = sigmoid(-diff);
                    let ip = sigmoid(diff);
                    let dip = db * g(pv[o]);
                    dps[j] = db * ip * g_grad(pv[o]);
                    let ddiff = ip * (1.0 - ip) * dip
                        - fp * (1.0 - fp) * da;
                    let dfs = unsafe { dfp.slice(o, 1) };
                    dfs[0] = -sigmoid(-f[o]) * ddiff;
                    dks[j] = sigmoid(-kv[o]) * ddiff;
                    carry[j] = fp * g_tot;
                } else {
                    // a = 1 - z, b = z·g(pre), z = σ(k)
                    let z = sigmoid(kv[o]);
                    let dz = db * g(pv[o]) - da;
                    dks[j] = dz * z * (1.0 - z);
                    dps[j] = db * z * g_grad(pv[o]);
                    carry[j] = (1.0 - z) * g_tot;
                }
            }
        }
    };
    if n < PAR_MIN_MAP || pool.active() == 1 {
        for idx in 0..batch * blocks {
            task(idx);
        }
    } else {
        pool.run(batch * blocks, task);
    }
}

// ---------------------------------------------------------------------------
// backward (full backbone)
// ---------------------------------------------------------------------------

/// Reverse-mode pass over a recorded [`Tape`]: accumulates `dL/dθ` into
/// `grads` (a [`NativeModel::zeros_like`] container; leaves are `+=`ed,
/// callers zero between steps).  `x` is the same input the forward saw.
pub fn backward(model: &NativeModel, tape: &Tape, x: &Tensor,
                dlogits: &[f32], grads: &mut NativeModel) -> Result<()> {
    let pool = threads::global();
    let (batch, t) = (tape.batch, tape.t);
    let rows = batch * t;
    let d = model.d_model;
    if dlogits.len() != rows * model.vocab_out {
        bail!("backward: dlogits {} != {} x {}", dlogits.len(), rows,
              model.vocab_out);
    }
    if model.blocks.len() != tape.blocks.len()
        || grads.blocks.len() != tape.blocks.len() {
        bail!("backward: model/tape/grads block counts disagree");
    }

    // head + final norm
    let mut du = Vec::new();
    dense_bwd(pool, &model.head, &tape.u_f, dlogits, rows,
              Some((&mut du, false)), &mut grads.head.w, &mut grads.head.b);
    let mut dh = Vec::new();
    rmsnorm_bwd(pool, &tape.h_fin, &model.ln_f, rows, d, &du, &mut dh,
                &mut grads.ln_f);

    // reusable buffers across blocks
    let mut dmix_in = Vec::new();
    let mut dtmp = Vec::new();
    let mut dbranch = Vec::new();

    for bi in (0..model.blocks.len()).rev() {
        let blk = &model.blocks[bi];
        let bt = &tape.blocks[bi];
        let gb = &mut grads.blocks[bi];

        // MLP branch: h = h_mid + drop(down(gelu(up(rmsnorm(h_mid, ln2)))))
        if let (Some(ln2), Some(mlp), Some(h_mid), Some(u2), Some(mlp_pre),
                Some(gln2), Some(gmlp)) =
            (&blk.ln2, &blk.mlp, &bt.h_mid, &bt.u2, &bt.mlp_pre,
             gb.ln2.as_deref_mut(), gb.mlp.as_mut()) {
            let mut act = Vec::new();
            map_pool(pool, mlp_pre, &mut act, gelu);
            // the branch's upstream gradient passes back through its
            // dropout mask; the residual passthrough (dh itself) does not
            let dz: &[f32] = match &bt.drop_mlp {
                Some(m) => {
                    mul_pool(pool, &dh, m, &mut dbranch);
                    &dbranch
                }
                None => &dh,
            };
            let mut dact = Vec::new();
            dense_bwd(pool, &mlp.down, &act, dz, rows,
                      Some((&mut dact, false)), &mut gmlp.down.w,
                      &mut gmlp.down.b);
            // through GELU
            for (da, &p) in dact.iter_mut().zip(mlp_pre.iter()) {
                *da *= gelu_grad(p);
            }
            dense_bwd(pool, &mlp.up, u2, &dact, rows,
                      Some((&mut du, false)), &mut gmlp.up.w,
                      &mut gmlp.up.b);
            rmsnorm_bwd(pool, h_mid, ln2, rows, d, &du, &mut dtmp, gln2);
            linalg::add_assign(&mut dh, &dtmp);
        }

        // mixer branch: h_mid = h_in + drop(mixer(mixer_in)) — the
        // kind-specific VJP is behind the trait; it overwrites dmix_in
        {
            let dy: &[f32] = match &bt.drop_mixer {
                Some(m) => {
                    mul_pool(pool, &dh, m, &mut dbranch);
                    &dbranch
                }
                None => &dh,
            };
            blk.mixer.m().backward(pool, &bt.mixer, &bt.mixer_in, dy,
                                   batch, t, &mut dmix_in,
                                   &mut gb.mixer)?;
        }

        // conv (if present), then RMSNorm 1, then the residual join
        let du1 = match (&blk.conv, &bt.conv_pre, gb.conv.as_mut()) {
            (Some(conv), Some(pre), Some(gconv)) => {
                conv4_bwd(pool, conv, &bt.u1, pre, &dmix_in, batch, t,
                          &mut dtmp, &mut gconv.w, &mut gconv.b);
                &dtmp
            }
            _ => &dmix_in,
        };
        rmsnorm_bwd(pool, &bt.h_in, &blk.ln1, rows, d, du1, &mut du,
                    &mut gb.ln1);
        linalg::add_assign(&mut dh, &du);
    }

    // positional table: every lane's row `min(ti, L-1)` sums its dh rows
    // (sequential scatter-add, deterministic like embed_bwd)
    if let (Some(pe), Some(gpe)) = (&model.pos, &mut grads.pos) {
        for bi in 0..batch {
            for ti in 0..t {
                let row = ti.min(pe.vocab - 1);
                let grow = &mut gpe.w[row * d..(row + 1) * d];
                let dhr = &dh[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                for i in 0..d {
                    grow[i] += dhr[i];
                }
            }
        }
    }

    // input layer
    match (&model.input, &mut grads.input, &x.data) {
        (InputLayer::Embed(e), InputLayer::Embed(ge), TensorData::I32(ids))
            => embed_bwd(ids, &dh, e.vocab, e.d, &mut ge.w),
        (InputLayer::Proj(p), InputLayer::Proj(gp), TensorData::F32(v)) => {
            dense_bwd(pool, p, v, &dh, rows, None, &mut gp.w, &mut gp.b);
        }
        _ => bail!("backward: input layer / grads / x dtype mismatch"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::model::NativeInit;

    fn tiny(kind: &str, conv: bool, mlp: bool) -> NativeModel {
        NativeModel::init_random(&NativeInit {
            kind: kind.to_string(),
            n_layers: 2,
            d_model: 6,
            expansion: 1,
            vocab_in: Some(9),
            input_dim: None,
            vocab_out: 9,
            conv,
            mlp,
            mlp_mult: 2,
            forget_bias: 1.0,
            max_len: 16,
            n_heads: 2,
        }, 5).unwrap()
    }

    const KINDS: [&str; 4] = ["mingru", "minlstm", "s6lite", "transformer"];

    #[test]
    fn train_forward_matches_inference_forward() {
        // the recording pass must produce the exact same logits as the
        // inference pass — same kernels, same order
        for kind in KINDS {
            let model = tiny(kind, true, true);
            let x = Tensor::i32(vec![2, 7],
                                (0..14).map(|i| (i % 9) as i32).collect());
            let tape = forward(&model, &x).unwrap();
            let (logits, _) = model.forward(&x).unwrap();
            assert_eq!(tape.logits, logits.data.as_f32().unwrap(),
                       "{kind}: train forward drifted from inference");
        }
    }

    #[test]
    fn backward_fills_every_leaf() {
        for kind in KINDS {
            let model = tiny(kind, true, true);
            let x = Tensor::i32(vec![1, 6], vec![1, 2, 3, 4, 5, 6]);
            let tape = forward(&model, &x).unwrap();
            let dlogits = vec![0.01f32; tape.logits.len()];
            let mut grads = model.zeros_like();
            backward(&model, &tape, &x, &dlogits, &mut grads).unwrap();
            for (name, leaf) in grads.leaf_names().iter()
                .zip(grads.leaves()) {
                let norm: f32 = leaf.iter().map(|v| v * v).sum();
                assert!(norm > 0.0, "{kind}: leaf '{name}' got no gradient");
                assert!(leaf.iter().all(|v| v.is_finite()),
                        "{kind}: leaf '{name}' has non-finite gradients");
            }
        }
    }

    #[test]
    fn zero_dropout_rate_is_bit_identical_to_plain_forward() {
        for kind in KINDS {
            let model = tiny(kind, true, true);
            let x = Tensor::i32(vec![2, 8], (0..16).map(|i| (i % 9) as i32)
                                .collect());
            let plain = forward(&model, &x).unwrap();
            // any seed: rate 0 must never sample, scale, or branch
            let trained = forward_train(&model, &x, 0.0, 0x5EED).unwrap();
            assert_eq!(plain.logits, trained.logits,
                       "{kind}: rate=0 drifted from the no-dropout path");
            for bt in &trained.blocks {
                assert!(bt.drop_mixer.is_none() && bt.drop_mlp.is_none());
            }
        }
    }

    #[test]
    fn dropout_masks_are_inverted_and_seed_keyed() {
        let model = tiny("mingru", false, true);
        let x = Tensor::i32(vec![2, 16], (0..32).map(|i| (i % 9) as i32)
                            .collect());
        let rate = 0.3f32;
        let tape = forward_train(&model, &x, rate, 7).unwrap();
        let scale = 1.0 / (1.0 - rate);
        let mut zeros = 0usize;
        let mut total = 0usize;
        for bt in &tape.blocks {
            for mask in [bt.drop_mixer.as_ref(), bt.drop_mlp.as_ref()]
                .into_iter().flatten() {
                for &m in mask {
                    assert!(m == 0.0 || (m - scale).abs() < 1e-6,
                            "multiplier {m} is neither 0 nor 1/(1-rate)");
                    zeros += usize::from(m == 0.0);
                    total += 1;
                }
            }
        }
        let frac = zeros as f64 / total as f64;
        assert!((frac - rate as f64).abs() < 0.08,
                "dropped fraction {frac} far from rate {rate}");
        // masks are a pure function of the seed: same seed → same tape,
        // different seed → different masks
        let again = forward_train(&model, &x, rate, 7).unwrap();
        assert_eq!(tape.logits, again.logits);
        let other = forward_train(&model, &x, rate, 8).unwrap();
        assert_ne!(tape.blocks[0].drop_mixer, other.blocks[0].drop_mixer);
        // mixer and MLP branches draw from distinct streams
        assert_ne!(tape.blocks[0].drop_mixer, tape.blocks[0].drop_mlp);
    }

    #[test]
    fn gradients_are_thread_count_invariant() {
        // same contract as the forward kernels: fixed task granularity
        // means bit-identical grads on 1 or N threads.  The global pool is
        // shared process state, so emulate via set_active.
        for kind in ["minlstm", "s6lite", "transformer"] {
            let model = tiny(kind, true, true);
            let x = Tensor::i32(vec![2, 9], (0..18).map(|i| (i % 9) as i32)
                                .collect());
            let tape = forward(&model, &x).unwrap();
            let mut dlogits = vec![0.0f32; tape.logits.len()];
            for (i, v) in dlogits.iter_mut().enumerate() {
                *v = ((i % 7) as f32 - 3.0) * 0.01;
            }
            let pool = threads::global();
            let before = pool.active();
            let mut grads1 = model.zeros_like();
            pool.set_active(1);
            backward(&model, &tape, &x, &dlogits, &mut grads1).unwrap();
            let mut grads_n = model.zeros_like();
            pool.set_active(pool.threads());
            backward(&model, &tape, &x, &dlogits, &mut grads_n).unwrap();
            pool.set_active(before);
            for ((a, b), name) in grads1.leaves().iter()
                .zip(grads_n.leaves()).zip(grads1.leaf_names()) {
                assert_eq!(*a, b,
                           "{kind}: leaf '{name}' differs across threads");
            }
        }
    }
}
