//! The mixer abstraction: one trait covering everything the backbone
//! needs from a sequence mixer — prefill, decode, recording forward,
//! VJP, and the per-lane decode-state layout.
//!
//! [`super::model::MixerParams`] stays a closed enum-of-impls (the MRNN
//! checkpoint format is versioned and closed), but every call site in
//! `model.rs` / `autograd.rs` dispatches through `&dyn Mixer` instead of
//! matching on the variant, so adding a mixer touches exactly three
//! places: its own file, the enum, and checkpoint probing.
//!
//! Four mixers implement the trait, completing the paper's comparison
//! matrix natively:
//!
//! | kind          | recurrence            | per-lane state        |
//! |---------------|-----------------------|-----------------------|
//! | `mingru`      | log-space scan        | `d_h` floats, O(1)    |
//! | `minlstm`     | log-space scan        | `d_h` floats, O(1)    |
//! | `s6lite`      | selective linear scan | `d_h` floats, O(1)    |
//! | `transformer` | causal attention      | `2·max_len·d`, O(T)   |
//!
//! The minGRU/minLSTM impls live here (thin adapters over the original
//! cell code plus the gate/scan VJP in `autograd`); S6-lite and the
//! transformer implement the trait in their own modules.

use anyhow::{bail, Result};

use crate::util::threads::{SlicePtr, ThreadPool};

use super::autograd;
use super::linalg::{log_g, softplus};
use super::mingru::{MinGru, GATE_CHUNK, H0_VALUE};
use super::minlstm::MinLstm;
use super::model::MixerParams;
use super::scan;
use super::scratch::MixerScratch;

/// Every mixer kind the native backend accepts, in canonical order —
/// the single source of truth for CLI validation and error messages.
pub const MIXER_KINDS: &[&str] = &["mingru", "minlstm", "s6lite",
                                   "transformer"];

/// `mingru|minlstm|s6lite|transformer` — for error messages.
pub fn kinds_help() -> String {
    MIXER_KINDS.join("|")
}

// ---------------------------------------------------------------------------
// trait
// ---------------------------------------------------------------------------

/// A sequence mixer behind the backbone's residual blocks.
///
/// State contract: a lane's decode state is a flat `[f32; state_len()]`
/// slice whose meaning is private to the mixer (hidden vector for the
/// recurrent mixers, K/V ring cache for attention).  `parallel_into`
/// consumes a fresh (`init_lane`d) state and leaves the post-prefix
/// state behind; `step_into` advances it by one token.  All entry points
/// keep the backend-wide invariant: results are bit-for-bit identical
/// at any thread count.
pub trait Mixer {
    /// Canonical kind string (one of [`MIXER_KINDS`]).
    fn kind(&self) -> &'static str;

    /// Hidden width of the mixer core (`d_h`).
    fn d_hidden(&self) -> usize;

    /// Per-lane decode-state length in f32s.  `d_h` for the recurrent
    /// mixers; `2·max_len·d` for the transformer's KV ring.
    fn state_len(&self) -> usize {
        self.d_hidden()
    }

    /// Write the fresh position-0 state into one lane's slice.
    fn init_lane(&self, lane: &mut [f32]);

    /// Parallel prefill.  `x: (B, T, d)` rows, `state: (B, state_len)`
    /// pre-initialized fresh; on return `y` holds `(B, T, d)` outputs
    /// and `state` the post-prefix decode state.
    #[allow(clippy::too_many_arguments)]
    fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                     t: usize, ms: &mut MixerScratch, y: &mut Vec<f32>,
                     state: &mut [f32]) -> Result<()>;

    /// One decode step.  `x_t: (B, d)`; `pos[b]` is the 0-based position
    /// of the incoming token in lane `b` (recurrent mixers ignore it).
    #[allow(clippy::too_many_arguments)]
    fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                 pos: &[u32], state: &mut [f32], ms: &mut MixerScratch,
                 y: &mut Vec<f32>) -> Result<()>;

    /// Recording forward for training: same math as `parallel_into`
    /// (from the fresh position-0 state), returning the activations the
    /// VJP needs plus the `(B, T, d)` output rows.
    fn forward_tape(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                    t: usize) -> Result<(MixerTape, Vec<f32>)>;

    /// VJP: consume the output gradient `dy`, accumulate parameter
    /// gradients into the matching `grads` variant, and write the input
    /// gradient into `dx` (overwriting, not accumulating).
    #[allow(clippy::too_many_arguments)]
    fn backward(&self, pool: &ThreadPool, tape: &MixerTape, x: &[f32],
                dy: &[f32], batch: usize, t: usize, dx: &mut Vec<f32>,
                grads: &mut MixerParams) -> Result<()>;
}

// ---------------------------------------------------------------------------
// tape
// ---------------------------------------------------------------------------

/// Per-mixer activations cached by [`Mixer::forward_tape`] for the VJP.
pub enum MixerTape {
    /// `linear_z` / `linear_h` pre-activations + scanned states.
    MinGru { k: Vec<f32>, pre: Vec<f32>, h: Vec<f32> },
    /// `linear_f` / `linear_i` / `linear_h` pre-activations + states.
    MinLstm { f: Vec<f32>, k: Vec<f32>, pre: Vec<f32>, h: Vec<f32> },
    /// `dt` / `b` / `gate` pre-projections + scanned states.
    S6Lite { dt_pre: Vec<f32>, bx: Vec<f32>, gate_pre: Vec<f32>,
             h: Vec<f32> },
    /// Fused QKV rows, attention probabilities `(B, H, T, T)`, and the
    /// merged pre-projection context `(B·T, d)`.
    Transformer { qkv: Vec<f32>, att: Vec<f32>, ctx: Vec<f32> },
}

// ---------------------------------------------------------------------------
// minGRU
// ---------------------------------------------------------------------------

/// Gate pre-activations → log-space scan coefficients for minGRU
/// (Algorithm 6): `log a = -softplus(k)`, `log b = -softplus(-k) +
/// log g(pre)`.  Fixed [`GATE_CHUNK`] task granularity.
fn mingru_log_coeffs(pool: &ThreadPool, k: &[f32], pre: &[f32],
                     log_a: &mut [f32], log_b: &mut [f32]) {
    let n = k.len();
    let lap = SlicePtr::new(log_a);
    let lbp = SlicePtr::new(log_b);
    pool.run_chunks(n, GATE_CHUNK, |s, e| {
        let la = unsafe { lap.slice(s, e - s) };
        let lb = unsafe { lbp.slice(s, e - s) };
        for i in 0..e - s {
            la[i] = -softplus(k[s + i]);
            lb[i] = -softplus(-k[s + i]) + log_g(pre[s + i]);
        }
    });
}

/// minLSTM (Algorithm 8): with `diff = softplus(-f) - softplus(-k)`,
/// `log a = -softplus(diff)`, `log b = -softplus(-diff) + log g(pre)`.
fn minlstm_log_coeffs(pool: &ThreadPool, f: &[f32], k: &[f32], pre: &[f32],
                      log_a: &mut [f32], log_b: &mut [f32]) {
    let n = k.len();
    let lap = SlicePtr::new(log_a);
    let lbp = SlicePtr::new(log_b);
    pool.run_chunks(n, GATE_CHUNK, |s, e| {
        let la = unsafe { lap.slice(s, e - s) };
        let lb = unsafe { lbp.slice(s, e - s) };
        for i in 0..e - s {
            let diff = softplus(-f[s + i]) - softplus(-k[s + i]);
            la[i] = -softplus(diff);
            lb[i] = -softplus(-diff) + log_g(pre[s + i]);
        }
    });
}

impl Mixer for MinGru {
    fn kind(&self) -> &'static str {
        "mingru"
    }

    fn d_hidden(&self) -> usize {
        MinGru::d_hidden(self)
    }

    fn init_lane(&self, lane: &mut [f32]) {
        lane.fill(H0_VALUE);
    }

    fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                     t: usize, ms: &mut MixerScratch, y: &mut Vec<f32>,
                     state: &mut [f32]) -> Result<()> {
        let h0 = state.to_vec();
        MinGru::parallel_into(self, pool, x, batch, t, &h0, ms, y, state);
        Ok(())
    }

    fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                 _pos: &[u32], state: &mut [f32], ms: &mut MixerScratch,
                 y: &mut Vec<f32>) -> Result<()> {
        MinGru::step_into(self, pool, x_t, batch, state, ms, y);
        Ok(())
    }

    fn forward_tape(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                    t: usize) -> Result<(MixerTape, Vec<f32>)> {
        let rows = batch * t;
        let dh = MinGru::d_hidden(self);
        let k = self.linear_z.apply_pool(pool, x, rows);
        let pre = self.linear_h.apply_pool(pool, x, rows);
        let mut log_a = vec![0.0f32; k.len()];
        let mut log_b = vec![0.0f32; k.len()];
        mingru_log_coeffs(pool, &k, &pre, &mut log_a, &mut log_b);
        let log_h0 = vec![H0_VALUE.ln(); batch * dh];
        let mut h = Vec::new();
        scan::scan_log_pool_into(pool, &log_a, &log_b, &log_h0, batch, t,
                                 dh, &mut h);
        let mut y = Vec::new();
        self.down.apply_pool_into(pool, &h, rows, &mut y);
        Ok((MixerTape::MinGru { k, pre, h }, y))
    }

    fn backward(&self, pool: &ThreadPool, tape: &MixerTape, x: &[f32],
                dy: &[f32], batch: usize, t: usize, dx: &mut Vec<f32>,
                grads: &mut MixerParams) -> Result<()> {
        let (k, pre, h) = match tape {
            MixerTape::MinGru { k, pre, h } => (k, pre, h),
            _ => bail!("minGRU backward: tape kind mismatch"),
        };
        let gm = match grads {
            MixerParams::MinGru(gm) => gm,
            _ => bail!("backward: grads mixer kind mismatch"),
        };
        let rows = batch * t;
        let dh = MinGru::d_hidden(self);
        let mut dh_seq = Vec::new();
        autograd::dense_bwd(pool, &self.down, h, dy, rows,
                            Some((&mut dh_seq, false)), &mut gm.down.w,
                            &mut gm.down.b);
        let (mut dk, mut dpre, mut df) = (Vec::new(), Vec::new(),
                                          Vec::new());
        autograd::scan_gate_bwd(pool, k, pre, None, h, batch, t, dh,
                                &dh_seq, &mut dk, &mut dpre, &mut df);
        autograd::dense_bwd(pool, &self.linear_z, x, &dk, rows,
                            Some((dx, false)), &mut gm.linear_z.w,
                            &mut gm.linear_z.b);
        autograd::dense_bwd(pool, &self.linear_h, x, &dpre, rows,
                            Some((dx, true)), &mut gm.linear_h.w,
                            &mut gm.linear_h.b);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// minLSTM
// ---------------------------------------------------------------------------

impl Mixer for MinLstm {
    fn kind(&self) -> &'static str {
        "minlstm"
    }

    fn d_hidden(&self) -> usize {
        MinLstm::d_hidden(self)
    }

    fn init_lane(&self, lane: &mut [f32]) {
        lane.fill(H0_VALUE);
    }

    fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                     t: usize, ms: &mut MixerScratch, y: &mut Vec<f32>,
                     state: &mut [f32]) -> Result<()> {
        let h0 = state.to_vec();
        MinLstm::parallel_into(self, pool, x, batch, t, &h0, ms, y, state);
        Ok(())
    }

    fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                 _pos: &[u32], state: &mut [f32], ms: &mut MixerScratch,
                 y: &mut Vec<f32>) -> Result<()> {
        MinLstm::step_into(self, pool, x_t, batch, state, ms, y);
        Ok(())
    }

    fn forward_tape(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                    t: usize) -> Result<(MixerTape, Vec<f32>)> {
        let rows = batch * t;
        let dh = MinLstm::d_hidden(self);
        let f = self.linear_f.apply_pool(pool, x, rows);
        let k = self.linear_i.apply_pool(pool, x, rows);
        let pre = self.linear_h.apply_pool(pool, x, rows);
        let mut log_a = vec![0.0f32; k.len()];
        let mut log_b = vec![0.0f32; k.len()];
        minlstm_log_coeffs(pool, &f, &k, &pre, &mut log_a, &mut log_b);
        let log_h0 = vec![H0_VALUE.ln(); batch * dh];
        let mut h = Vec::new();
        scan::scan_log_pool_into(pool, &log_a, &log_b, &log_h0, batch, t,
                                 dh, &mut h);
        let mut y = Vec::new();
        self.down.apply_pool_into(pool, &h, rows, &mut y);
        Ok((MixerTape::MinLstm { f, k, pre, h }, y))
    }

    fn backward(&self, pool: &ThreadPool, tape: &MixerTape, x: &[f32],
                dy: &[f32], batch: usize, t: usize, dx: &mut Vec<f32>,
                grads: &mut MixerParams) -> Result<()> {
        let (f, k, pre, h) = match tape {
            MixerTape::MinLstm { f, k, pre, h } => (f, k, pre, h),
            _ => bail!("minLSTM backward: tape kind mismatch"),
        };
        let gm = match grads {
            MixerParams::MinLstm(gm) => gm,
            _ => bail!("backward: grads mixer kind mismatch"),
        };
        let rows = batch * t;
        let dh = MinLstm::d_hidden(self);
        let mut dh_seq = Vec::new();
        autograd::dense_bwd(pool, &self.down, h, dy, rows,
                            Some((&mut dh_seq, false)), &mut gm.down.w,
                            &mut gm.down.b);
        let (mut dk, mut dpre, mut df) = (Vec::new(), Vec::new(),
                                          Vec::new());
        autograd::scan_gate_bwd(pool, k, pre, Some(f), h, batch, t, dh,
                                &dh_seq, &mut dk, &mut dpre, &mut df);
        autograd::dense_bwd(pool, &self.linear_f, x, &df, rows,
                            Some((dx, false)), &mut gm.linear_f.w,
                            &mut gm.linear_f.b);
        autograd::dense_bwd(pool, &self.linear_i, x, &dk, rows,
                            Some((dx, true)), &mut gm.linear_i.w,
                            &mut gm.linear_i.b);
        autograd::dense_bwd(pool, &self.linear_h, x, &dpre, rows,
                            Some((dx, true)), &mut gm.linear_h.w,
                            &mut gm.linear_h.b);
        Ok(())
    }
}
