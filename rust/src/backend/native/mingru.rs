//! minGRU mixer (Section 3.1) for the native backend: parallel mode via
//! the log-space scan (Algorithm 6), sequential decode (Algorithm 5).
//! Mirrors `python/compile/models/mingru.py`.

use super::linalg::{g, log_g, sigmoid, softplus, Dense};
use super::scan;

/// `g(0) = 0.5` — the positive resting hidden state the log-space
/// formulation starts from.
pub const H0_VALUE: f32 = 0.5;

#[derive(Clone, Debug)]
pub struct MinGru {
    pub linear_z: Dense,
    pub linear_h: Dense,
    pub down: Dense,
}

impl MinGru {
    pub fn d_hidden(&self) -> usize {
        self.linear_z.d_out
    }

    /// Parallel mode.  `x: (B, T, d_model)`, `h0: (B, d_h)` →
    /// `(y: (B, T, d_model), h_T: (B, d_h))`.
    pub fn parallel(&self, x: &[f32], batch: usize, t: usize, h0: &[f32])
                    -> (Vec<f32>, Vec<f32>) {
        let rows = batch * t;
        let k = self.linear_z.apply(x, rows);
        let pre = self.linear_h.apply(x, rows);
        let dh = self.d_hidden();
        let n = rows * dh;
        // Algorithm 6: log(1-z) = -softplus(k); log z = -softplus(-k)
        let mut log_a = vec![0.0f32; n];
        let mut log_b = vec![0.0f32; n];
        for i in 0..n {
            log_a[i] = -softplus(k[i]);
            log_b[i] = -softplus(-k[i]) + log_g(pre[i]);
        }
        let log_h0: Vec<f32> = h0.iter().map(|&v| v.ln()).collect();
        let h = scan::scan_log(&log_a, &log_b, &log_h0, batch, t, dh);
        let y = self.down.apply(&h, rows);
        let mut h_last = vec![0.0f32; batch * dh];
        for bi in 0..batch {
            h_last[bi * dh..(bi + 1) * dh].copy_from_slice(
                &h[(bi * t + t - 1) * dh..(bi * t + t) * dh]);
        }
        (y, h_last)
    }

    /// One decode step (Algorithm 5): `z = σ(k)`,
    /// `h' = (1-z) ⊙ h + z ⊙ g(pre)`.  Updates `h` in place, returns `y`.
    pub fn step(&self, x_t: &[f32], batch: usize, h: &mut [f32]) -> Vec<f32> {
        let k = self.linear_z.apply(x_t, batch);
        let pre = self.linear_h.apply(x_t, batch);
        debug_assert_eq!(h.len(), batch * self.d_hidden());
        for i in 0..h.len() {
            let z = sigmoid(k[i]);
            h[i] = (1.0 - z) * h[i] + z * g(pre[i]);
        }
        self.down.apply(h, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dense(rng: &mut Rng, d_in: usize, d_out: usize) -> Dense {
        let scale = 1.0 / (d_in as f32).sqrt();
        Dense::new(d_in, d_out,
                   (0..d_in * d_out).map(|_| rng.normal_f32(0.0, scale))
                       .collect(),
                   vec![0.0; d_out]).unwrap()
    }

    fn random_mingru(rng: &mut Rng, d: usize, dh: usize) -> MinGru {
        MinGru {
            linear_z: random_dense(rng, d, dh),
            linear_h: random_dense(rng, d, dh),
            down: random_dense(rng, dh, d),
        }
    }

    #[test]
    fn parallel_matches_sequential_decode() {
        // The paper's core identity at the mixer level.
        let mut rng = Rng::new(31);
        let (batch, t, d, dh) = (2usize, 24usize, 4usize, 6usize);
        let cell = random_mingru(&mut rng, d, dh);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h0 = vec![H0_VALUE; batch * dh];
        let (y_par, h_last) = cell.parallel(&x, batch, t, &h0);

        let mut h = h0.clone();
        for ti in 0..t {
            let mut xt = vec![0.0f32; batch * d];
            for bi in 0..batch {
                xt[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let y_t = cell.step(&xt, batch, &mut h);
            for bi in 0..batch {
                for di in 0..d {
                    let p = y_par[(bi * t + ti) * d + di];
                    let s = y_t[bi * d + di];
                    assert!((p - s).abs() < 1e-4,
                            "t={ti} b={bi} d={di}: {p} vs {s}");
                }
            }
        }
        for i in 0..h.len() {
            assert!((h[i] - h_last[i]).abs() < 1e-4,
                    "h_last[{i}]: {} vs {}", h[i], h_last[i]);
        }
    }
}
