//! minGRU mixer (Section 3.1) for the native backend: parallel mode via
//! the log-space scan (Algorithm 6), sequential decode (Algorithm 5).
//! Mirrors `python/compile/models/mingru.py`.
//!
//! The `*_into` entry points are allocation-free: gate pre-activations,
//! log-space operands, and the scanned state sequence live in a
//! [`MixerScratch`]; GEMMs and the scan fan out across the given
//! [`ThreadPool`].  The plain `parallel`/`step` wrappers keep the PR-1
//! allocating API on the global pool.

use super::linalg::{self, g, log_g, sigmoid, softplus, Dense};
use super::scan;
use super::scratch::MixerScratch;
use crate::util::threads::{self, SlicePtr, ThreadPool};

/// `g(0) = 0.5` — the positive resting hidden state the log-space
/// formulation starts from.
pub const H0_VALUE: f32 = 0.5;

/// Elementwise gate maps fan out in chunks of this many elements
/// (fixed, so results are thread-count invariant).
pub(crate) const GATE_CHUNK: usize = 1 << 12;

#[derive(Clone, Debug)]
pub struct MinGru {
    pub linear_z: Dense,
    pub linear_h: Dense,
    pub down: Dense,
}

impl MinGru {
    pub fn d_hidden(&self) -> usize {
        self.linear_z.d_out
    }

    /// Parallel mode.  `x: (B, T, d_model)`, `h0: (B, d_h)` →
    /// `(y: (B, T, d_model), h_T: (B, d_h))`.
    pub fn parallel(&self, x: &[f32], batch: usize, t: usize, h0: &[f32])
                    -> (Vec<f32>, Vec<f32>) {
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut h_last = vec![0.0f32; batch * self.d_hidden()];
        self.parallel_into(threads::global(), x, batch, t, h0, &mut ms,
                           &mut y, &mut h_last);
        (y, h_last)
    }

    /// Allocation-free parallel mode: `y` receives `(B, T, d_model)`
    /// outputs, `h_last` (len `B * d_h`) the final hidden state.
    #[allow(clippy::too_many_arguments)]
    pub fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                         t: usize, h0: &[f32], ms: &mut MixerScratch,
                         y: &mut Vec<f32>, h_last: &mut [f32]) {
        let rows = batch * t;
        let dh = self.d_hidden();
        debug_assert_eq!(h0.len(), batch * dh);
        debug_assert_eq!(h_last.len(), batch * dh);
        self.linear_z.apply_pool_into(pool, x, rows, &mut ms.k);
        self.linear_h.apply_pool_into(pool, x, rows, &mut ms.pre);
        let n = rows * dh;
        // Algorithm 6: log(1-z) = -softplus(k); log z = -softplus(-k)
        linalg::reuse(&mut ms.log_a, n);
        linalg::reuse(&mut ms.log_b, n);
        {
            let lap = SlicePtr::new(ms.log_a.as_mut_slice());
            let lbp = SlicePtr::new(ms.log_b.as_mut_slice());
            let k = &ms.k;
            let pre = &ms.pre;
            pool.run_chunks(n, GATE_CHUNK, |s, e| {
                let la = unsafe { lap.slice(s, e - s) };
                let lb = unsafe { lbp.slice(s, e - s) };
                for i in 0..e - s {
                    la[i] = -softplus(k[s + i]);
                    lb[i] = -softplus(-k[s + i]) + log_g(pre[s + i]);
                }
            });
        }
        linalg::reuse(&mut ms.log_h0, batch * dh);
        for (l, &v) in ms.log_h0.iter_mut().zip(h0) {
            // a zero channel would give ln(0) = -inf and a negative one
            // NaN; clamp to the scan's absorbing log-zero sentinel, which
            // keeps the channel inert exactly like h0 = 0 in real space
            *l = if v > 0.0 { v.ln() } else { scan::LOG_ZERO };
        }
        scan::scan_log_pool_into(pool, &ms.log_a, &ms.log_b, &ms.log_h0,
                                 batch, t, dh, &mut ms.h);
        self.down.apply_pool_into(pool, &ms.h, rows, y);
        for bi in 0..batch {
            h_last[bi * dh..(bi + 1) * dh].copy_from_slice(
                &ms.h[(bi * t + t - 1) * dh..(bi * t + t) * dh]);
        }
    }

    /// One decode step (Algorithm 5): `z = σ(k)`,
    /// `h' = (1-z) ⊙ h + z ⊙ g(pre)`.  Updates `h` in place, returns `y`.
    pub fn step(&self, x_t: &[f32], batch: usize, h: &mut [f32]) -> Vec<f32> {
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        self.step_into(threads::global(), x_t, batch, h, &mut ms, &mut y);
        y
    }

    /// Allocation-free decode step.  The gate update is sequential
    /// (per-token work is tiny); the three GEMMs parallelize themselves
    /// by size.
    pub fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                     h: &mut [f32], ms: &mut MixerScratch,
                     y: &mut Vec<f32>) {
        self.linear_z.apply_pool_into(pool, x_t, batch, &mut ms.k);
        self.linear_h.apply_pool_into(pool, x_t, batch, &mut ms.pre);
        debug_assert_eq!(h.len(), batch * self.d_hidden());
        for i in 0..h.len() {
            let z = sigmoid(ms.k[i]);
            h[i] = (1.0 - z) * h[i] + z * g(ms.pre[i]);
        }
        self.down.apply_pool_into(pool, h, batch, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dense(rng: &mut Rng, d_in: usize, d_out: usize) -> Dense {
        let scale = 1.0 / (d_in as f32).sqrt();
        Dense::new(d_in, d_out,
                   (0..d_in * d_out).map(|_| rng.normal_f32(0.0, scale))
                       .collect(),
                   vec![0.0; d_out]).unwrap()
    }

    fn random_mingru(rng: &mut Rng, d: usize, dh: usize) -> MinGru {
        MinGru {
            linear_z: random_dense(rng, d, dh),
            linear_h: random_dense(rng, d, dh),
            down: random_dense(rng, dh, d),
        }
    }

    #[test]
    fn zero_h0_parallel_matches_sequential_decode() {
        // regression: log_h0 = ln(0) = -inf used to poison the scan; the
        // clamp to scan::LOG_ZERO must reproduce the sequential decode
        // path starting from h = 0 (and stay finite for negative h0)
        let mut rng = Rng::new(77);
        let (batch, t, d, dh) = (2usize, 11usize, 3usize, 4usize);
        let cell = random_mingru(&mut rng, d, dh);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for h0_val in [0.0f32, -0.25] {
            let h0 = vec![h0_val; batch * dh];
            let (y_par, h_last) = cell.parallel(&x, batch, t, &h0);
            assert!(y_par.iter().all(|v| v.is_finite()),
                    "h0={h0_val}: non-finite parallel output");
            assert!(h_last.iter().all(|v| v.is_finite()));
            if h0_val != 0.0 {
                continue; // sequential decode keeps the sign; the clamp
                          // treats any non-positive channel as empty
            }
            let mut h = h0.clone();
            for ti in 0..t {
                let mut xt = vec![0.0f32; batch * d];
                for bi in 0..batch {
                    xt[bi * d..(bi + 1) * d].copy_from_slice(
                        &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
                }
                let y_t = cell.step(&xt, batch, &mut h);
                for bi in 0..batch {
                    for di in 0..d {
                        let p = y_par[(bi * t + ti) * d + di];
                        let s = y_t[bi * d + di];
                        assert!((p - s).abs() < 1e-4,
                                "h0=0 t={ti} b={bi} d={di}: {p} vs {s}");
                    }
                }
            }
        }
    }

    #[test]
    fn parallel_matches_sequential_decode() {
        // The paper's core identity at the mixer level.
        let mut rng = Rng::new(31);
        let (batch, t, d, dh) = (2usize, 24usize, 4usize, 6usize);
        let cell = random_mingru(&mut rng, d, dh);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h0 = vec![H0_VALUE; batch * dh];
        let (y_par, h_last) = cell.parallel(&x, batch, t, &h0);

        let mut h = h0.clone();
        for ti in 0..t {
            let mut xt = vec![0.0f32; batch * d];
            for bi in 0..batch {
                xt[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let y_t = cell.step(&xt, batch, &mut h);
            for bi in 0..batch {
                for di in 0..d {
                    let p = y_par[(bi * t + ti) * d + di];
                    let s = y_t[bi * d + di];
                    assert!((p - s).abs() < 1e-4,
                            "t={ti} b={bi} d={di}: {p} vs {s}");
                }
            }
        }
        for i in 0..h.len() {
            assert!((h[i] - h_last[i]).abs() < 1e-4,
                    "h_last[{i}]: {} vs {}", h[i], h_last[i]);
        }
    }
}
