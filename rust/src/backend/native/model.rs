//! Native backbone (Appendix C.2), mirroring
//! `python/compile/models/backbone.py` for the natively-supported mixers
//! (minGRU, minLSTM, S6-lite, causal transformer):
//!
//! ```text
//! x → Embed (or in_proj for continuous features) [+ pos, transformer]
//!   → N × [ RMSNorm → (Conv4) → mixer → +residual
//!           (RMSNorm → MLP → +residual) ]
//!   → RMSNorm → Head
//! ```
//!
//! Parameters load from the MRNN checkpoint format (`util::io`) using the
//! same leaf names the AOT manifest/checkpoints use
//! (`params/blocks/0/mixer/linear_z/w`, ...), so a model trained through
//! the PJRT path serves natively with zero conversion.  A seeded random
//! init is provided for artifact-free smoke runs.
//!
//! Mixer math lives behind the [`Mixer`] trait (`mixer.rs`); this module
//! owns the closed, versioned parameter enum ([`MixerParams`]) plus the
//! backbone plumbing around it.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::tensor::{Tensor, TensorData};
use crate::util::io::{self, NamedTensor};
use crate::util::rng::Rng;
use crate::util::threads::{self, ThreadPool};

use super::linalg::{self, Conv4, Dense, Embedding, Mlp, CONV_K};
use super::mingru::MinGru;
use super::minlstm::MinLstm;
use super::mixer::{kinds_help, Mixer};
use super::quant::{self, QuantDense};
use super::s6lite::S6Lite;
use super::scratch::NativeScratch;
use super::transformer::Transformer;

// ---------------------------------------------------------------------------
// parameter tree
// ---------------------------------------------------------------------------

/// The closed set of mixers a checkpoint can carry.  Kept as an enum —
/// not trait objects — so the MRNN format stays a closed, versioned
/// surface; behavior dispatches through [`MixerParams::m`].
#[derive(Clone, Debug)]
pub enum MixerParams {
    MinGru(MinGru),
    MinLstm(MinLstm),
    S6Lite(S6Lite),
    Transformer(Transformer),
}

impl MixerParams {
    /// The mixer behavior behind this parameter set.
    pub fn m(&self) -> &dyn Mixer {
        match self {
            MixerParams::MinGru(m) => m,
            MixerParams::MinLstm(m) => m,
            MixerParams::S6Lite(m) => m,
            MixerParams::Transformer(m) => m,
        }
    }

    pub fn d_hidden(&self) -> usize {
        self.m().d_hidden()
    }

    pub fn kind(&self) -> &'static str {
        self.m().kind()
    }

    /// Per-lane decode-state length in f32s ([`Mixer::state_len`]).
    pub fn state_len(&self) -> usize {
        self.m().state_len()
    }
}

#[derive(Clone, Debug)]
pub struct BlockParams {
    pub ln1: Vec<f32>,
    pub conv: Option<Conv4>,
    pub mixer: MixerParams,
    pub ln2: Option<Vec<f32>>,
    pub mlp: Option<Mlp>,
}

#[derive(Clone, Debug)]
pub enum InputLayer {
    /// Token embedding for discrete inputs (`vocab_in`).
    Embed(Embedding),
    /// Linear projection for continuous features (`input_dim`, RL).
    Proj(Dense),
}

#[derive(Clone, Debug)]
pub struct NativeModel {
    pub d_model: usize,
    pub vocab_out: usize,
    pub input: InputLayer,
    /// Learned absolute positional embeddings — transformer backbones
    /// only (`params/pos/w`, `(max_len, d)`; lookups clamp to the last
    /// row past `max_len`, like `backbone.py`'s `jnp.take`).
    pub pos: Option<Embedding>,
    pub blocks: Vec<BlockParams>,
    pub ln_f: Vec<f32>,
    pub head: Dense,
}

/// Per-layer decode state: the mixer's per-lane state (hidden vector for
/// recurrent mixers, KV ring cache for attention) + optional conv ring.
#[derive(Clone, Debug)]
pub struct LayerState {
    pub h: Vec<f32>,
    pub conv: Option<Vec<f32>>,
}

/// Full decode state for a batch of lanes.  Carries the reusable
/// [`NativeScratch`] so decode through the by-value `Backend::decode_step`
/// API stays allocation-free at steady state.
#[derive(Clone, Debug)]
pub struct NativeState {
    pub batch: usize,
    /// Batch-global step counter (informational; drives serve logs).
    pub pos: usize,
    /// Per-lane 0-based position of the *next* token — diverges from
    /// `pos` once continuous batching resets individual lanes.  Drives
    /// the positional lookup and the transformer's ring-slot addressing.
    pub lane_pos: Vec<u32>,
    pub layers: Vec<LayerState>,
    pub scratch: NativeScratch,
}

// ---------------------------------------------------------------------------
// random init (artifact-free smoke runs)
// ---------------------------------------------------------------------------

/// Architecture hyperparameters for [`NativeModel::init_random`]; mirrors
/// the `cfg` dict of `backbone.py` for the natively-supported mixers.
#[derive(Clone, Debug)]
pub struct NativeInit {
    pub kind: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub expansion: usize,
    pub vocab_in: Option<usize>,
    pub input_dim: Option<usize>,
    pub vocab_out: usize,
    pub conv: bool,
    pub mlp: bool,
    pub mlp_mult: usize,
    pub forget_bias: f32,
    /// Positional-table length / KV-cache capacity (transformer only).
    pub max_len: usize,
    /// Attention heads (transformer only; must divide `d_model`).
    pub n_heads: usize,
}

impl Default for NativeInit {
    fn default() -> Self {
        NativeInit {
            kind: "mingru".to_string(),
            n_layers: 2,
            d_model: 64,
            expansion: 1,
            vocab_in: Some(64),
            input_dim: None,
            vocab_out: 64,
            conv: false,
            mlp: false,
            mlp_mult: 4,
            forget_bias: 0.0,
            max_len: 256,
            n_heads: 4,
        }
    }
}

fn dense_random(rng: &mut Rng, d_in: usize, d_out: usize, scale: f32,
                bias: f32) -> Dense {
    Dense {
        d_in,
        d_out,
        w: (0..d_in * d_out).map(|_| rng.normal_f32(0.0, scale)).collect(),
        b: vec![bias; d_out],
        q: None,
    }
}

impl NativeModel {
    /// LeCun-normal random init (like `layers.dense_init`); numerics differ
    /// from the JAX PRNG, so this is for artifact-free smoke runs, not for
    /// reproducing an XLA-initialized model.
    pub fn init_random(cfg: &NativeInit, seed: u64) -> Result<NativeModel> {
        let d = cfg.d_model;
        let dh = d * cfg.expansion;
        let mut rng = Rng::new(seed ^ 0x6E61_7469_7665);
        let input = match (cfg.vocab_in, cfg.input_dim) {
            (Some(v), _) => InputLayer::Embed(Embedding {
                vocab: v,
                d,
                w: (0..v * d).map(|_| rng.normal_f32(0.0, 0.02)).collect(),
            }),
            (None, Some(f)) => InputLayer::Proj(dense_random(
                &mut rng, f, d, 1.0 / (f as f32).sqrt(), 0.0)),
            (None, None) => bail!("need vocab_in or input_dim"),
        };
        let lecun = |rng: &mut Rng, d_in: usize, d_out: usize, bias: f32| {
            dense_random(rng, d_in, d_out, 1.0 / (d_in as f32).sqrt(), bias)
        };
        if cfg.kind == "transformer" && (cfg.n_heads == 0
                                         || d % cfg.n_heads != 0) {
            bail!("transformer: d_model {d} not divisible by n_heads {}",
                  cfg.n_heads);
        }
        // learned absolute positions (transformer backbones only), like
        // backbone.py's params["pos"]
        let pos = (cfg.kind == "transformer").then(|| Embedding {
            vocab: cfg.max_len.max(1),
            d,
            w: (0..cfg.max_len.max(1) * d)
                .map(|_| rng.normal_f32(0.0, 0.02)).collect(),
        });
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            let mixer = match cfg.kind.as_str() {
                "mingru" => MixerParams::MinGru(MinGru {
                    linear_z: lecun(&mut rng, d, dh, 0.0),
                    linear_h: lecun(&mut rng, d, dh, 0.0),
                    down: lecun(&mut rng, dh, d, 0.0),
                }),
                "minlstm" => MixerParams::MinLstm(MinLstm {
                    linear_f: lecun(&mut rng, d, dh, cfg.forget_bias),
                    linear_i: lecun(&mut rng, d, dh, 0.0),
                    linear_h: lecun(&mut rng, d, dh, 0.0),
                    down: lecun(&mut rng, dh, d, 0.0),
                }),
                // s6lite.py: dt bias -1 keeps Δ = softplus(dt(x)) small
                // at init; a_log spans log(linspace(1, 8, d_h))
                "s6lite" => MixerParams::S6Lite(S6Lite {
                    dt: lecun(&mut rng, d, dh, -1.0),
                    b: lecun(&mut rng, d, dh, 0.0),
                    gate: lecun(&mut rng, d, dh, 0.0),
                    down: lecun(&mut rng, dh, d, 0.0),
                    a_log: (0..dh).map(|j| {
                        let lin = if dh > 1 {
                            1.0 + 7.0 * j as f32 / (dh - 1) as f32
                        } else {
                            1.0
                        };
                        lin.ln()
                    }).collect(),
                }),
                "transformer" => MixerParams::Transformer(Transformer {
                    qkv: lecun(&mut rng, d, 3 * d, 0.0),
                    proj: dense_random(&mut rng, d, d, 0.02, 0.0),
                    n_heads: cfg.n_heads,
                    max_len: cfg.max_len.max(1),
                }),
                other => bail!("unknown mixer kind '{other}' — the native \
                                backend supports {}", kinds_help()),
            };
            let conv = if cfg.conv {
                Some(Conv4 {
                    k: CONV_K,
                    d,
                    w: (0..CONV_K * d)
                        .map(|_| rng.normal_f32(0.0,
                                                1.0 / (CONV_K as f32).sqrt()))
                        .collect(),
                    b: vec![0.0; d],
                })
            } else {
                None
            };
            let (ln2, mlp) = if cfg.mlp {
                (Some(vec![1.0; d]),
                 Some(Mlp {
                     up: lecun(&mut rng, d, cfg.mlp_mult * d, 0.0),
                     down: lecun(&mut rng, cfg.mlp_mult * d, d, 0.0),
                 }))
            } else {
                (None, None)
            };
            blocks.push(BlockParams { ln1: vec![1.0; d], conv, mixer,
                                      ln2, mlp });
        }
        Ok(NativeModel {
            d_model: d,
            vocab_out: cfg.vocab_out,
            input,
            pos,
            blocks,
            ln_f: vec![1.0; d],
            head: dense_random(&mut rng, d, cfg.vocab_out, 0.02, 0.0),
        })
    }

    // -----------------------------------------------------------------------
    // checkpoint I/O
    // -----------------------------------------------------------------------

    pub fn from_checkpoint(path: &Path) -> Result<NativeModel> {
        NativeModel::from_named(&io::load(path)?)
    }

    /// Build from named tensors using the AOT/checkpoint leaf naming
    /// (an optional `params/` prefix is accepted on every leaf; extra
    /// tensors such as optimizer state are ignored).
    pub fn from_named(tensors: &[NamedTensor]) -> Result<NativeModel> {
        let find = |name: &str| -> Option<&NamedTensor> {
            tensors.iter().find(|t| {
                t.name == name
                    || t.name.strip_prefix("params/") == Some(name)
            })
        };
        let tensor_f32 = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
            let t = find(name)
                .ok_or_else(|| anyhow!("checkpoint missing '{name}'"))?;
            let v = t.data.as_f32()
                .ok_or_else(|| anyhow!("'{name}' is not f32"))?;
            Ok((t.dims.clone(), v.to_vec()))
        };
        // a dense leaf is either f32 (`{name}/w`) or the v3 int8 pair
        // (`{name}/q` + `{name}/scale`, see `super::quant`)
        let dense = |name: &str| -> Result<Dense> {
            let (_, b) = tensor_f32(&format!("{name}/b"))?;
            if find(&format!("{name}/q")).is_none() {
                let (wd, w) = tensor_f32(&format!("{name}/w"))?;
                if wd.len() != 2 {
                    bail!("'{name}/w' is not a matrix: dims {wd:?}");
                }
                return Dense::new(wd[0], wd[1], w, b);
            }
            let qn = format!("{name}/q");
            let qt = find(&qn).unwrap();
            let q = qt.data.as_i8()
                .ok_or_else(|| anyhow!("'{qn}' is not i8"))?.to_vec();
            let qd = qt.dims.clone();
            if qd.len() != 2 {
                bail!("'{qn}' is not a matrix: dims {qd:?}");
            }
            let (d_in, d_out) = (qd[0], qd[1]);
            if q.len() != d_in * d_out || b.len() != d_out {
                bail!("'{qn}' shape mismatch: {} != {d_in}x{d_out}, \
                       b {} != {d_out}", q.len(), b.len());
            }
            let (sd, scales) = tensor_f32(&format!("{name}/scale"))?;
            if sd.len() != 2 || sd[0] != quant::n_kt(d_in)
                || sd[1] != quant::n_ct(d_out) {
                bail!("'{name}/scale' dims {sd:?} do not match a \
                       ({d_in}, {d_out}) int8 matrix (want ({}, {}))",
                      quant::n_kt(d_in), quant::n_ct(d_out));
            }
            Ok(Dense { d_in, d_out, w: Vec::new(), b,
                       q: Some(QuantDense { q, scales }) })
        };
        // mixer-kind probes must see both encodings
        let has_dense = |name: &str| -> bool {
            find(&format!("{name}/w")).is_some()
                || find(&format!("{name}/q")).is_some()
        };

        let (input, d_model) = if find("embed/w").is_some() {
            let (dims, w) = tensor_f32("embed/w")?;
            if dims.len() != 2 {
                bail!("'embed/w' is not a matrix: dims {dims:?}");
            }
            (InputLayer::Embed(Embedding::new(dims[0], dims[1], w)?),
             dims[1])
        } else {
            let proj = dense("in_proj")?;
            let d = proj.d_out;
            (InputLayer::Proj(proj), d)
        };

        // learned positional table (transformer checkpoints)
        let pos = match find("pos/w") {
            Some(_) => {
                let (dims, w) = tensor_f32("pos/w")?;
                if dims.len() != 2 || dims[1] != d_model {
                    bail!("'pos/w' dims {dims:?} do not match d_model \
                           {d_model}");
                }
                Some(Embedding::new(dims[0], dims[1], w)?)
            }
            None => None,
        };
        // attention head count rides along as metadata (i32 or f32
        // scalar); absent in older checkpoints → the backbone.py default
        let n_heads = match find("meta/n_heads") {
            Some(t) => match (&t.data, t.data.as_f32()) {
                (TensorData::I32(v), _) if !v.is_empty() => v[0] as usize,
                (_, Some(v)) if !v.is_empty() => v[0] as usize,
                _ => bail!("'meta/n_heads' is empty"),
            },
            None => 4,
        };

        let mut blocks = Vec::new();
        let mut i = 0usize;
        while find(&format!("blocks/{i}/ln1/scale")).is_some() {
            let (_, ln1) = tensor_f32(&format!("blocks/{i}/ln1/scale"))?;
            let mixer = if has_dense(&format!("blocks/{i}/mixer/linear_f"))
            {
                MixerParams::MinLstm(MinLstm {
                    linear_f: dense(&format!("blocks/{i}/mixer/linear_f"))?,
                    linear_i: dense(&format!("blocks/{i}/mixer/linear_i"))?,
                    linear_h: dense(&format!("blocks/{i}/mixer/linear_h"))?,
                    down: dense(&format!("blocks/{i}/mixer/down"))?,
                })
            } else if has_dense(&format!("blocks/{i}/mixer/linear_z")) {
                MixerParams::MinGru(MinGru {
                    linear_z: dense(&format!("blocks/{i}/mixer/linear_z"))?,
                    linear_h: dense(&format!("blocks/{i}/mixer/linear_h"))?,
                    down: dense(&format!("blocks/{i}/mixer/down"))?,
                })
            } else if has_dense(&format!("blocks/{i}/mixer/dt")) {
                let (ad, a_log) =
                    tensor_f32(&format!("blocks/{i}/mixer/a_log"))?;
                if ad.len() != 1 {
                    bail!("'blocks/{i}/mixer/a_log' dims {ad:?}");
                }
                MixerParams::S6Lite(S6Lite {
                    dt: dense(&format!("blocks/{i}/mixer/dt"))?,
                    b: dense(&format!("blocks/{i}/mixer/b"))?,
                    gate: dense(&format!("blocks/{i}/mixer/gate"))?,
                    down: dense(&format!("blocks/{i}/mixer/down"))?,
                    a_log,
                })
            } else if has_dense(&format!("blocks/{i}/mixer/qkv")) {
                let pe = pos.as_ref().ok_or_else(|| anyhow!(
                    "block {i} is a transformer but the checkpoint has no \
                     'pos/w' positional table"))?;
                let m = Transformer {
                    qkv: dense(&format!("blocks/{i}/mixer/qkv"))?,
                    proj: dense(&format!("blocks/{i}/mixer/proj"))?,
                    n_heads,
                    max_len: pe.vocab,
                };
                m.check()?;
                MixerParams::Transformer(m)
            } else {
                bail!("block {i}: unrecognized mixer parameters — the \
                       native backend supports {}", kinds_help());
            };
            let conv = match find(&format!("blocks/{i}/conv/w")) {
                Some(_) => {
                    let (wd, w) = tensor_f32(&format!("blocks/{i}/conv/w"))?;
                    let (_, b) = tensor_f32(&format!("blocks/{i}/conv/b"))?;
                    if wd.len() != 2 {
                        bail!("'blocks/{i}/conv/w' dims {wd:?}");
                    }
                    Some(Conv4::new(wd[0], wd[1], w, b)?)
                }
                None => None,
            };
            let (ln2, mlp) =
                match find(&format!("blocks/{i}/ln2/scale")) {
                    Some(_) => {
                        let (_, s) =
                            tensor_f32(&format!("blocks/{i}/ln2/scale"))?;
                        (Some(s), Some(Mlp {
                            up: dense(&format!("blocks/{i}/mlp/up"))?,
                            down: dense(&format!("blocks/{i}/mlp/down"))?,
                        }))
                    }
                    None => (None, None),
                };
            blocks.push(BlockParams { ln1, conv, mixer, ln2, mlp });
            i += 1;
        }
        if blocks.is_empty() {
            bail!("checkpoint has no 'blocks/0/ln1/scale' — not a backbone \
                   parameter set");
        }
        // homogeneity: a mixed-kind stack would make `kind()` (and every
        // serve log / fingerprint derived from it) a lie — reject early
        let kind0 = blocks[0].mixer.kind();
        if let Some((i, blk)) = blocks.iter().enumerate()
            .find(|(_, b)| b.mixer.kind() != kind0) {
            bail!("mixed mixer kinds: block 0 is {kind0} but block {i} is \
                   {} — the native backbone requires one kind throughout",
                  blk.mixer.kind());
        }
        if pos.is_some() && kind0 != "transformer" {
            bail!("checkpoint has a 'pos/w' positional table but {kind0} \
                   blocks — not a transformer backbone");
        }
        let (_, ln_f) = tensor_f32("ln_f/scale")?;
        let head = dense("head")?;
        let vocab_out = head.d_out;
        Ok(NativeModel { d_model, vocab_out, input, pos, blocks, ln_f,
                         head })
    }

    /// Export as named tensors (with the `params/` prefix), the inverse of
    /// [`NativeModel::from_named`].
    pub fn to_named(&self) -> Vec<NamedTensor> {
        let mut out = Vec::new();
        let dense = |out: &mut Vec<NamedTensor>, name: String, d: &Dense| {
            match &d.q {
                Some(qd) => {
                    out.push(NamedTensor::i8(&format!("{name}/q"),
                                             vec![d.d_in, d.d_out],
                                             qd.q.clone()));
                    out.push(NamedTensor::f32(
                        &format!("{name}/scale"),
                        vec![quant::n_kt(d.d_in), quant::n_ct(d.d_out)],
                        qd.scales.clone()));
                }
                None => out.push(NamedTensor::f32(
                    &format!("{name}/w"), vec![d.d_in, d.d_out],
                    d.w.clone())),
            }
            out.push(NamedTensor::f32(&format!("{name}/b"),
                                      vec![d.d_out], d.b.clone()));
        };
        match &self.input {
            InputLayer::Embed(e) => out.push(NamedTensor::f32(
                "params/embed/w", vec![e.vocab, e.d], e.w.clone())),
            InputLayer::Proj(p) => dense(&mut out,
                                         "params/in_proj".to_string(), p),
        }
        if let Some(pe) = &self.pos {
            out.push(NamedTensor::f32("params/pos/w",
                                      vec![pe.vocab, pe.d], pe.w.clone()));
        }
        for (i, blk) in self.blocks.iter().enumerate() {
            out.push(NamedTensor::f32(&format!("params/blocks/{i}/ln1/scale"),
                                      vec![blk.ln1.len()], blk.ln1.clone()));
            if let Some(c) = &blk.conv {
                out.push(NamedTensor::f32(
                    &format!("params/blocks/{i}/conv/w"),
                    vec![c.k, c.d], c.w.clone()));
                out.push(NamedTensor::f32(
                    &format!("params/blocks/{i}/conv/b"),
                    vec![c.d], c.b.clone()));
            }
            match &blk.mixer {
                MixerParams::MinGru(m) => {
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/linear_z"),
                          &m.linear_z);
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/linear_h"),
                          &m.linear_h);
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/down"), &m.down);
                }
                MixerParams::MinLstm(m) => {
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/linear_f"),
                          &m.linear_f);
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/linear_i"),
                          &m.linear_i);
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/linear_h"),
                          &m.linear_h);
                    dense(&mut out,
                          format!("params/blocks/{i}/mixer/down"), &m.down);
                }
                MixerParams::S6Lite(m) => {
                    dense(&mut out, format!("params/blocks/{i}/mixer/dt"),
                          &m.dt);
                    dense(&mut out, format!("params/blocks/{i}/mixer/b"),
                          &m.b);
                    dense(&mut out, format!("params/blocks/{i}/mixer/gate"),
                          &m.gate);
                    dense(&mut out, format!("params/blocks/{i}/mixer/down"),
                          &m.down);
                    out.push(NamedTensor::f32(
                        &format!("params/blocks/{i}/mixer/a_log"),
                        vec![m.a_log.len()], m.a_log.clone()));
                }
                MixerParams::Transformer(m) => {
                    dense(&mut out, format!("params/blocks/{i}/mixer/qkv"),
                          &m.qkv);
                    dense(&mut out, format!("params/blocks/{i}/mixer/proj"),
                          &m.proj);
                }
            }
            if let Some(s) = &blk.ln2 {
                out.push(NamedTensor::f32(
                    &format!("params/blocks/{i}/ln2/scale"),
                    vec![s.len()], s.clone()));
            }
            if let Some(m) = &blk.mlp {
                dense(&mut out, format!("params/blocks/{i}/mlp/up"), &m.up);
                dense(&mut out, format!("params/blocks/{i}/mlp/down"),
                      &m.down);
            }
        }
        out.push(NamedTensor::f32("params/ln_f/scale",
                                  vec![self.ln_f.len()], self.ln_f.clone()));
        dense(&mut out, "params/head".to_string(), &self.head);
        // non-parameter metadata rides last; `leaf_names` filters it so
        // the positional leaf walks (optimizer state) never see it
        if let Some(MixerParams::Transformer(m)) =
            self.blocks.first().map(|b| &b.mixer) {
            out.push(NamedTensor::i32("meta/n_heads", vec![1],
                                      vec![m.n_heads as i32]));
        }
        out
    }

    // -----------------------------------------------------------------------
    // parameter leaves (training support)
    // -----------------------------------------------------------------------
    //
    // `leaf_names`, `leaves`, and `leaves_mut` walk the parameter tree in
    // one canonical order — the `to_named` order.  The three bodies must
    // stay in lockstep: optimizer state (`adam::AdamState`) and gradient
    // checks index leaves positionally through them.

    /// Leaf names in canonical order, matching [`NativeModel::to_named`]
    /// minus the non-parameter `meta/` tensors (including the `params/`
    /// prefix).
    pub fn leaf_names(&self) -> Vec<String> {
        self.to_named().into_iter().map(|t| t.name)
            .filter(|n| !n.starts_with("meta/")).collect()
    }

    /// All parameter leaves in canonical order (shared refs).
    pub fn leaves(&self) -> Vec<&Vec<f32>> {
        let mut out: Vec<&Vec<f32>> = Vec::new();
        match &self.input {
            InputLayer::Embed(e) => out.push(&e.w),
            InputLayer::Proj(p) => {
                out.push(&p.w);
                out.push(&p.b);
            }
        }
        if let Some(pe) = &self.pos {
            out.push(&pe.w);
        }
        for blk in &self.blocks {
            out.push(&blk.ln1);
            if let Some(c) = &blk.conv {
                out.push(&c.w);
                out.push(&c.b);
            }
            match &blk.mixer {
                MixerParams::MinGru(m) => {
                    for d in [&m.linear_z, &m.linear_h, &m.down] {
                        out.push(&d.w);
                        out.push(&d.b);
                    }
                }
                MixerParams::MinLstm(m) => {
                    for d in [&m.linear_f, &m.linear_i, &m.linear_h,
                              &m.down] {
                        out.push(&d.w);
                        out.push(&d.b);
                    }
                }
                MixerParams::S6Lite(m) => {
                    for d in [&m.dt, &m.b, &m.gate, &m.down] {
                        out.push(&d.w);
                        out.push(&d.b);
                    }
                    out.push(&m.a_log);
                }
                MixerParams::Transformer(m) => {
                    for d in [&m.qkv, &m.proj] {
                        out.push(&d.w);
                        out.push(&d.b);
                    }
                }
            }
            if let Some(s) = &blk.ln2 {
                out.push(s);
            }
            if let Some(m) = &blk.mlp {
                for d in [&m.up, &m.down] {
                    out.push(&d.w);
                    out.push(&d.b);
                }
            }
        }
        out.push(&self.ln_f);
        out.push(&self.head.w);
        out.push(&self.head.b);
        out
    }

    /// All parameter leaves in canonical order (mutable refs).
    pub fn leaves_mut(&mut self) -> Vec<&mut Vec<f32>> {
        let mut out: Vec<&mut Vec<f32>> = Vec::new();
        match &mut self.input {
            InputLayer::Embed(e) => out.push(&mut e.w),
            InputLayer::Proj(p) => {
                out.push(&mut p.w);
                out.push(&mut p.b);
            }
        }
        if let Some(pe) = &mut self.pos {
            out.push(&mut pe.w);
        }
        for blk in &mut self.blocks {
            out.push(&mut blk.ln1);
            if let Some(c) = &mut blk.conv {
                out.push(&mut c.w);
                out.push(&mut c.b);
            }
            match &mut blk.mixer {
                MixerParams::MinGru(m) => {
                    for d in [&mut m.linear_z, &mut m.linear_h,
                              &mut m.down] {
                        out.push(&mut d.w);
                        out.push(&mut d.b);
                    }
                }
                MixerParams::MinLstm(m) => {
                    for d in [&mut m.linear_f, &mut m.linear_i,
                              &mut m.linear_h, &mut m.down] {
                        out.push(&mut d.w);
                        out.push(&mut d.b);
                    }
                }
                MixerParams::S6Lite(m) => {
                    for d in [&mut m.dt, &mut m.b, &mut m.gate,
                              &mut m.down] {
                        out.push(&mut d.w);
                        out.push(&mut d.b);
                    }
                    out.push(&mut m.a_log);
                }
                MixerParams::Transformer(m) => {
                    for d in [&mut m.qkv, &mut m.proj] {
                        out.push(&mut d.w);
                        out.push(&mut d.b);
                    }
                }
            }
            if let Some(s) = &mut blk.ln2 {
                out.push(s);
            }
            if let Some(m) = &mut blk.mlp {
                for d in [&mut m.up, &mut m.down] {
                    out.push(&mut d.w);
                    out.push(&mut d.b);
                }
            }
        }
        out.push(&mut self.ln_f);
        out.push(&mut self.head.w);
        out.push(&mut self.head.b);
        out
    }

    /// A same-shaped model with every parameter zeroed — gradient storage
    /// for `backend::native::autograd`.
    pub fn zeros_like(&self) -> NativeModel {
        let mut z = self.clone();
        for leaf in z.leaves_mut() {
            leaf.iter_mut().for_each(|v| *v = 0.0);
        }
        z
    }

    /// Visit every [`Dense`] layer (the quantizable leaves) in canonical
    /// order.  Embeddings, conv taps, and norm gains are not visited —
    /// they stay f32 under quantization.
    pub fn for_each_dense(&self, f: &mut dyn FnMut(&Dense)) {
        if let InputLayer::Proj(p) = &self.input {
            f(p);
        }
        for blk in &self.blocks {
            match &blk.mixer {
                MixerParams::MinGru(m) => {
                    for d in [&m.linear_z, &m.linear_h, &m.down] {
                        f(d);
                    }
                }
                MixerParams::MinLstm(m) => {
                    for d in [&m.linear_f, &m.linear_i, &m.linear_h,
                              &m.down] {
                        f(d);
                    }
                }
                MixerParams::S6Lite(m) => {
                    for d in [&m.dt, &m.b, &m.gate, &m.down] {
                        f(d);
                    }
                }
                MixerParams::Transformer(m) => {
                    for d in [&m.qkv, &m.proj] {
                        f(d);
                    }
                }
            }
            if let Some(m) = &blk.mlp {
                f(&m.up);
                f(&m.down);
            }
        }
        f(&self.head);
    }

    /// Mutable twin of [`NativeModel::for_each_dense`] — the hook
    /// `quant::quantize_model` converts layers through.
    pub fn for_each_dense_mut(&mut self, f: &mut dyn FnMut(&mut Dense)) {
        if let InputLayer::Proj(p) = &mut self.input {
            f(p);
        }
        for blk in &mut self.blocks {
            match &mut blk.mixer {
                MixerParams::MinGru(m) => {
                    for d in [&mut m.linear_z, &mut m.linear_h,
                              &mut m.down] {
                        f(d);
                    }
                }
                MixerParams::MinLstm(m) => {
                    for d in [&mut m.linear_f, &mut m.linear_i,
                              &mut m.linear_h, &mut m.down] {
                        f(d);
                    }
                }
                MixerParams::S6Lite(m) => {
                    for d in [&mut m.dt, &mut m.b, &mut m.gate,
                              &mut m.down] {
                        f(d);
                    }
                }
                MixerParams::Transformer(m) => {
                    for d in [&mut m.qkv, &mut m.proj] {
                        f(d);
                    }
                }
            }
            if let Some(m) = &mut blk.mlp {
                f(&mut m.up);
                f(&mut m.down);
            }
        }
        f(&mut self.head);
    }

    /// True when any dense layer carries an int8 payload.  Quantized
    /// models are inference-only (the trainer refuses them) and
    /// fingerprint differently from their f32 source (see
    /// [`NativeModel::state_fingerprint`]).
    pub fn is_quantized(&self) -> bool {
        let mut any = false;
        self.for_each_dense(&mut |d| any |= d.q.is_some());
        any
    }

    // -----------------------------------------------------------------------
    // inference
    // -----------------------------------------------------------------------

    /// Fresh decode state: each lane's mixer state at its position-0
    /// value ([`Mixer::init_lane`] — `g(0) = 0.5` for the minimal RNNs,
    /// zeros for S6-lite and the KV ring), conv buffers and the position
    /// counters at zero.
    pub fn init_state(&self, batch: usize) -> NativeState {
        let layers = self.blocks.iter().map(|blk| {
            let sl = blk.mixer.state_len();
            let mut h = vec![0.0f32; batch * sl];
            for lane in h.chunks_mut(sl.max(1)) {
                blk.mixer.m().init_lane(lane);
            }
            LayerState {
                h,
                conv: blk.conv.as_ref().map(|c| c.zero_state(batch)),
            }
        }).collect();
        NativeState { batch, pos: 0, lane_pos: vec![0; batch], layers,
                      scratch: NativeScratch::default() }
    }

    /// Reset one decode lane to the fresh position-0 state (mixer state
    /// re-initialized, conv ring buffer zeroed, lane position back to 0)
    /// without touching the other lanes — the primitive behind
    /// continuous-batching lane refill in `coordinator::server`.
    pub fn reset_lane(&self, state: &mut NativeState, lane: usize)
                      -> Result<()> {
        if lane >= state.batch {
            bail!("reset_lane: lane {lane} >= batch {}", state.batch);
        }
        for (blk, st) in self.blocks.iter().zip(state.layers.iter_mut()) {
            let sl = blk.mixer.state_len();
            blk.mixer.m().init_lane(&mut st.h[lane * sl..(lane + 1) * sl]);
            if let (Some(conv), Some(buf)) = (&blk.conv, st.conv.as_mut()) {
                let w = (conv.k - 1) * conv.d;
                buf[lane * w..(lane + 1) * w].fill(0.0);
            }
        }
        state.lane_pos[lane] = 0;
        Ok(())
    }

    /// Fingerprint of the decode-state layout: folds a layout version,
    /// the model dims, and each block's (mixer kind, state length, conv
    /// ring-buffer width) through `splitmix64`.  Two models agree exactly
    /// when a lane exported from one ([`NativeModel::export_lane`]) can
    /// be imported into the other.  minGRU/minLSTM fingerprints are
    /// unchanged from layout v1 (state length == hidden width there), so
    /// session caches written before the mixer refactor stay valid.
    ///
    /// Quantized models fold in an extra marker: their decode-state
    /// *layout* matches the f32 source (cache state stays f32), but the
    /// logits the states were computed under differ, so a session
    /// snapshot exported from the f32 model must not silently import
    /// into its int8 twin (or vice versa).  f32 fingerprints are
    /// unchanged, keeping existing session caches valid.
    pub fn state_fingerprint(&self) -> u64 {
        let mut fields: Vec<u64> = vec![
            1, // state-layout version
            self.d_model as u64,
            self.vocab_out as u64,
            self.blocks.len() as u64,
        ];
        for blk in &self.blocks {
            fields.push(match blk.mixer.kind() {
                "mingru" => 1,
                "minlstm" => 2,
                "s6lite" => 3,
                _ => 4,
            });
            fields.push(blk.mixer.state_len() as u64);
            fields.push(blk.conv.as_ref()
                .map(|c| ((c.k - 1) * c.d) as u64).unwrap_or(0));
        }
        if self.is_quantized() {
            fields.push(0x6938_5131_7131_0001); // int8-weights marker
        }
        let mut fp = 0u64;
        for f in fields {
            let mut s = fp ^ f;
            fp = crate::util::rng::splitmix64(&mut s);
        }
        fp
    }

    /// Byte length of one exported lane: 4 bytes per f32 of mixer state
    /// plus conv ring buffer per block, plus a 4-byte lane-position
    /// header on positional (transformer) backbones.  O(1) in context
    /// length for the recurrent mixers, O(max_len · d) for attention —
    /// the session-cache contrast the comparison matrix is about.
    pub fn lane_state_bytes(&self) -> usize {
        let header = if self.pos.is_some() { 4 } else { 0 };
        header + self.blocks.iter().map(|blk| {
            let mut n = blk.mixer.state_len();
            if let Some(conv) = &blk.conv {
                n += (conv.k - 1) * conv.d;
            }
            n * 4
        }).sum::<usize>()
    }

    /// Serialize one decode lane (positional backbones: the lane's
    /// position counter first; then per block the mixer state and the
    /// conv ring buffer if present) to little-endian bytes.  The
    /// transformer's KV ring is exported verbatim — slot addressing is a
    /// pure function of the preserved position, so re-imported lanes
    /// re-attend bit-identically.  The batch-global `pos` counter is
    /// informational only and is not part of a lane's state.
    pub fn export_lane(&self, state: &NativeState, lane: usize)
                       -> Result<Vec<u8>> {
        if lane >= state.batch {
            bail!("export_lane: lane {lane} >= batch {}", state.batch);
        }
        let mut out = Vec::with_capacity(self.lane_state_bytes());
        if self.pos.is_some() {
            out.extend_from_slice(&state.lane_pos[lane].to_le_bytes());
        }
        for (blk, st) in self.blocks.iter().zip(state.layers.iter()) {
            let sl = blk.mixer.state_len();
            for &v in &st.h[lane * sl..(lane + 1) * sl] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            if let (Some(conv), Some(buf)) = (&blk.conv, st.conv.as_ref()) {
                let w = (conv.k - 1) * conv.d;
                for &v in &buf[lane * w..(lane + 1) * w] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        Ok(out)
    }

    /// Overwrite one decode lane from bytes produced by
    /// [`NativeModel::export_lane`] on an identically-shaped model,
    /// leaving every other lane untouched.  A wrong byte count fails
    /// cleanly before anything is written.
    pub fn import_lane(&self, state: &mut NativeState, lane: usize,
                       bytes: &[u8]) -> Result<()> {
        if lane >= state.batch {
            bail!("import_lane: lane {lane} >= batch {}", state.batch);
        }
        let want = self.lane_state_bytes();
        if bytes.len() != want {
            bail!("import_lane: snapshot is {} bytes but this model's \
                   lane state is {want}", bytes.len());
        }
        let mut off = 0usize;
        if self.pos.is_some() {
            state.lane_pos[lane] = u32::from_le_bytes(
                [bytes[0], bytes[1], bytes[2], bytes[3]]);
            off = 4;
        }
        let read_f32 = |off: &mut usize| {
            let v = f32::from_le_bytes([bytes[*off], bytes[*off + 1],
                                        bytes[*off + 2], bytes[*off + 3]]);
            *off += 4;
            v
        };
        for (blk, st) in self.blocks.iter().zip(state.layers.iter_mut()) {
            let sl = blk.mixer.state_len();
            for v in st.h[lane * sl..(lane + 1) * sl].iter_mut() {
                *v = read_f32(&mut off);
            }
            if let (Some(conv), Some(buf)) = (&blk.conv, st.conv.as_mut()) {
                let w = (conv.k - 1) * conv.d;
                for v in buf[lane * w..(lane + 1) * w].iter_mut() {
                    *v = read_f32(&mut off);
                }
            }
        }
        Ok(())
    }

    pub(crate) fn embed_rows_into(&self, x: &Tensor, rows: usize,
                                  out: &mut Vec<f32>) -> Result<()> {
        match (&self.input, &x.data) {
            (InputLayer::Embed(e), TensorData::I32(ids)) => {
                if ids.len() != rows {
                    bail!("expected {rows} token ids, got {}", ids.len());
                }
                e.lookup_into(ids, out);
                Ok(())
            }
            (InputLayer::Proj(p), TensorData::F32(v)) => {
                if v.len() != rows * p.d_in {
                    bail!("expected {rows}x{} features, got {}", p.d_in,
                          v.len());
                }
                p.apply_into(v, rows, out);
                Ok(())
            }
            (InputLayer::Embed(_), _) => {
                bail!("model embeds token ids; got f32 input")
            }
            (InputLayer::Proj(_), _) => {
                bail!("model projects continuous features; got i32 input")
            }
        }
    }

    /// One decode step.  `x_t`: `(B,)` i32 tokens or `(B, F)` f32 features.
    /// Returns `(logits: (B, vocab_out), state')`.
    ///
    /// All intermediates live in the state's [`NativeScratch`]; at steady
    /// state the only heap allocation is the returned logits tensor.
    pub fn step(&self, x_t: &Tensor, mut state: NativeState)
                -> Result<(Tensor, NativeState)> {
        let batch = state.batch;
        if x_t.dims.first().copied().unwrap_or(0) != batch {
            bail!("step input batch {:?} != state batch {batch}", x_t.dims);
        }
        let pool = threads::global();
        let d = self.d_model;
        {
            let NativeState { layers, scratch: s, lane_pos, .. } =
                &mut state;
            self.embed_rows_into(x_t, batch, &mut s.h)?;
            // positional embedding: each lane looks up its own position
            // (clamped to the last row, like backbone.py's jnp.take)
            if let Some(pe) = &self.pos {
                for (bi, &p) in lane_pos.iter().enumerate() {
                    let row = (p as usize).min(pe.vocab - 1);
                    let prow = &pe.w[row * d..(row + 1) * d];
                    let hrow = &mut s.h[bi * d..(bi + 1) * d];
                    for i in 0..d {
                        hrow[i] += prow[i];
                    }
                }
            }
            for (blk, st) in self.blocks.iter().zip(layers.iter_mut()) {
                linalg::rmsnorm_pool_into(pool, &s.h, &blk.ln1, batch, d,
                                          &mut s.u);
                if let (Some(conv), Some(buf)) = (&blk.conv,
                                                  st.conv.as_mut()) {
                    conv.step_into(buf, &s.u, batch, &mut s.y);
                    std::mem::swap(&mut s.u, &mut s.y);
                }
                blk.mixer.m().step_into(pool, &s.u, batch, lane_pos,
                                        &mut st.h, &mut s.mixer,
                                        &mut s.y)?;
                linalg::add_assign(&mut s.h, &s.y);
                if let (Some(ln2), Some(mlp)) = (&blk.ln2, &blk.mlp) {
                    linalg::rmsnorm_pool_into(pool, &s.h, ln2, batch, d,
                                              &mut s.u);
                    mlp.apply_pool_into(pool, &s.u, batch, &mut s.mlp_h,
                                        &mut s.z);
                    linalg::add_assign(&mut s.h, &s.z);
                }
            }
            linalg::rmsnorm_pool_into(pool, &s.h, &self.ln_f, batch, d,
                                      &mut s.u);
            for p in lane_pos.iter_mut() {
                *p += 1;
            }
        }
        let mut logits = Vec::new(); // handed to the caller inside a Tensor
        self.head.apply_pool_into(pool, &state.scratch.u, batch,
                                  &mut logits);
        state.pos += 1;
        Ok((Tensor::f32(vec![batch, self.vocab_out], logits), state))
    }

    /// Parallel forward over a whole context.  `x`: `(B, T)` i32 or
    /// `(B, T, F)` f32.  Returns all-position logits `(B, T, vocab_out)`
    /// and the decode state after the last position.  Per-layer work
    /// (GEMMs, gate maps, the log-space scan, RMSNorm, conv) fans out
    /// across the global thread pool.
    pub fn forward(&self, x: &Tensor) -> Result<(Tensor, NativeState)> {
        let (batch, t) = match (x.dims.len(), &x.data) {
            (2, TensorData::I32(_)) => (x.dims[0], x.dims[1]),
            (3, TensorData::F32(_)) => (x.dims[0], x.dims[1]),
            _ => bail!("forward expects (B, T) i32 or (B, T, F) f32, got \
                        {:?} {}", x.dims, x.dtype_name()),
        };
        if t == 0 {
            bail!("empty sequence");
        }
        let pool = threads::global();
        let rows = batch * t;
        let d = self.d_model;
        let mut s = NativeScratch::default();
        self.embed_rows_into(x, rows, &mut s.h)?;
        // positional embedding — position `ti` for every lane (clamped,
        // matching the decode path; prefill lengths past the table are
        // rejected by the transformer mixer before this matters)
        if let Some(pe) = &self.pos {
            for bi in 0..batch {
                for ti in 0..t {
                    let row = ti.min(pe.vocab - 1);
                    let prow = &pe.w[row * d..(row + 1) * d];
                    let hrow =
                        &mut s.h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                    for i in 0..d {
                        hrow[i] += prow[i];
                    }
                }
            }
        }
        let mut layers = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            linalg::rmsnorm_pool_into(pool, &s.h, &blk.ln1, rows, d,
                                      &mut s.u);
            let conv_state = match &blk.conv {
                Some(conv) => {
                    let st = conv.final_state(&s.u, batch, t);
                    conv.parallel_pool_into(pool, &s.u, batch, t, &mut s.y);
                    std::mem::swap(&mut s.u, &mut s.y);
                    Some(st)
                }
                None => None,
            };
            let sl = blk.mixer.state_len();
            let mut mixer_state = vec![0.0f32; batch * sl];
            for lane in mixer_state.chunks_mut(sl.max(1)) {
                blk.mixer.m().init_lane(lane);
            }
            blk.mixer.m().parallel_into(pool, &s.u, batch, t, &mut s.mixer,
                                        &mut s.y, &mut mixer_state)?;
            linalg::add_assign(&mut s.h, &s.y);
            if let (Some(ln2), Some(mlp)) = (&blk.ln2, &blk.mlp) {
                linalg::rmsnorm_pool_into(pool, &s.h, ln2, rows, d,
                                          &mut s.u);
                mlp.apply_pool_into(pool, &s.u, rows, &mut s.mlp_h,
                                    &mut s.z);
                linalg::add_assign(&mut s.h, &s.z);
            }
            layers.push(LayerState { h: mixer_state, conv: conv_state });
        }
        linalg::rmsnorm_pool_into(pool, &s.h, &self.ln_f, rows, d,
                                  &mut s.u);
        let mut logits = Vec::new();
        self.head.apply_pool_into(pool, &s.u, rows, &mut logits);
        // Drop the prefill-sized scratch (O(B*T*d) buffers) instead of
        // pinning it inside the decode state for its whole lifetime —
        // decode only needs O(B*d) buffers and re-warms them on the
        // first step.
        Ok((Tensor::f32(vec![batch, t, self.vocab_out], logits),
            NativeState { batch, pos: t, lane_pos: vec![t as u32; batch],
                          layers, scratch: NativeScratch::default() }))
    }

    /// Parallel prefill: last-position logits `(B, vocab_out)` + state,
    /// matching the PJRT prefill calling convention.
    pub fn prefill(&self, x: &Tensor) -> Result<(Tensor, NativeState)> {
        let (all, state) = self.forward(x)?;
        let (batch, t) = (all.dims[0], all.dims[1]);
        let v = self.vocab_out;
        let data = all.data.as_f32()
            .ok_or_else(|| anyhow!("logits not f32"))?;
        let mut last = vec![0.0f32; batch * v];
        for bi in 0..batch {
            last[bi * v..(bi + 1) * v].copy_from_slice(
                &data[(bi * t + t - 1) * v..(bi * t + t) * v]);
        }
        Ok((Tensor::f32(vec![batch, v], last), state))
    }

    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// The stack's mixer kind.  Construction (random init and checkpoint
    /// load) enforces that every block uses the same mixer, so the first
    /// block speaks for all of them.
    pub fn kind(&self) -> &'static str {
        self.blocks.first().map(|b| b.mixer.kind()).unwrap_or("empty")
    }

    /// Human-readable block summary for `describe`/serve logs, spelling
    /// out the per-block count rather than a bare kind: `"2×transformer"`.
    pub fn kind_summary(&self) -> String {
        let q = if self.is_quantized() { " int8" } else { "" };
        format!("{}×{}{q}", self.blocks.len(), self.kind())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model(kind: &str, conv: bool, mlp: bool) -> NativeModel {
        NativeModel::init_random(&NativeInit {
            kind: kind.to_string(),
            n_layers: 2,
            d_model: 8,
            expansion: 2,
            vocab_in: Some(11),
            input_dim: None,
            vocab_out: 11,
            conv,
            mlp,
            mlp_mult: 2,
            forget_bias: 0.5,
            max_len: 16,
            n_heads: 2,
        }, 7).unwrap()
    }

    #[test]
    fn forward_and_step_agree() {
        // the paper's parallel/sequential identity through the full stack
        for kind in ["mingru", "minlstm", "s6lite", "transformer"] {
            let model = tiny_model(kind, true, true);
            let (batch, t) = (2usize, 9usize);
            let mut rng = crate::util::rng::Rng::new(3);
            let tokens: Vec<i32> = (0..batch * t)
                .map(|_| rng.below(11) as i32).collect();
            let x = Tensor::i32(vec![batch, t], tokens.clone());
            let (all, pstate) = model.forward(&x).unwrap();
            assert_eq!(all.dims, vec![batch, t, 11]);
            let all_v = all.data.as_f32().unwrap();

            let mut st = model.init_state(batch);
            for ti in 0..t {
                let xt = Tensor::i32(
                    vec![batch],
                    (0..batch).map(|bi| tokens[bi * t + ti]).collect());
                let (logits, st2) = model.step(&xt, st).unwrap();
                st = st2;
                let lv = logits.data.as_f32().unwrap();
                for bi in 0..batch {
                    for vi in 0..11 {
                        let p = all_v[(bi * t + ti) * 11 + vi];
                        let s = lv[bi * 11 + vi];
                        assert!((p - s).abs() < 1e-4,
                                "{kind} t={ti} b={bi} v={vi}: {p} vs {s}");
                    }
                }
            }
            assert_eq!(st.pos, pstate.pos);
            for (a, b) in st.layers.iter().zip(&pstate.layers) {
                for (x1, x2) in a.h.iter().zip(&b.h) {
                    assert!((x1 - x2).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn named_roundtrip_is_exact() {
        for kind in ["minlstm", "s6lite", "transformer"] {
            let model = tiny_model(kind, true, true);
            let named = model.to_named();
            let back = NativeModel::from_named(&named).unwrap();
            let x = Tensor::i32(vec![1, 5], vec![1, 2, 3, 4, 5]);
            let (a, _) = model.forward(&x).unwrap();
            let (b, _) = back.forward(&x).unwrap();
            assert_eq!(a, b, "{kind}: roundtrip must be bit-exact");
            assert_eq!(back.kind(), kind);
        }
    }

    #[test]
    fn quantized_named_roundtrip_is_exact() {
        // int8 leaves survive to_named → from_named bit for bit, and the
        // quantized model fingerprints differently from its f32 source
        for kind in ["mingru", "s6lite", "transformer"] {
            let model = tiny_model(kind, true, true);
            let fp_f32 = model.state_fingerprint();
            let mut qm = model.clone();
            quant::quantize_model(&mut qm).unwrap();
            assert!(qm.is_quantized() && !model.is_quantized());
            assert_ne!(qm.state_fingerprint(), fp_f32,
                       "{kind}: quantization must change the fingerprint");
            assert!(qm.kind_summary().contains("int8"), "{kind}");
            let back = NativeModel::from_named(&qm.to_named()).unwrap();
            assert!(back.is_quantized());
            assert_eq!(back.state_fingerprint(), qm.state_fingerprint());
            let x = Tensor::i32(vec![1, 5], vec![1, 2, 3, 4, 5]);
            let (a, _) = qm.forward(&x).unwrap();
            let (b, _) = back.forward(&x).unwrap();
            assert_eq!(a, b, "{kind}: quantized roundtrip must be exact");
        }
    }

    #[test]
    fn leaf_walks_stay_in_lockstep() {
        // leaf_names / leaves / leaves_mut / to_named must enumerate the
        // same leaves in the same order — optimizer state is positional
        // (to_named may carry trailing non-parameter `meta/` tensors,
        // which every leaf walk skips)
        for (kind, conv, mlp) in [("mingru", true, true),
                                  ("minlstm", false, true),
                                  ("minlstm", true, false),
                                  ("s6lite", true, true),
                                  ("transformer", true, true)] {
            let mut model = tiny_model(kind, conv, mlp);
            let names = model.leaf_names();
            let named: Vec<NamedTensor> = model.to_named().into_iter()
                .filter(|t| !t.name.starts_with("meta/")).collect();
            assert_eq!(names.len(), named.len());
            let shared_lens: Vec<usize> =
                model.leaves().iter().map(|l| l.len()).collect();
            let mut_lens: Vec<usize> =
                model.leaves_mut().iter().map(|l| l.len()).collect();
            assert_eq!(shared_lens, mut_lens, "{kind}");
            for ((name, nt), len) in names.iter().zip(&named)
                .zip(&shared_lens) {
                assert_eq!(name, &nt.name);
                assert_eq!(nt.data.len(), *len,
                           "{kind}: leaf '{name}' length drifted");
            }
            // zeros_like matches shapes and zeroes every value
            let z = model.zeros_like();
            for (a, b) in z.leaves().iter().zip(model.leaves()) {
                assert_eq!(a.len(), b.len());
                assert!(a.iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn rejects_garbage_checkpoints() {
        assert!(NativeModel::from_named(&[]).is_err());
        let named = vec![NamedTensor::f32("params/embed/w", vec![4, 4],
                                          vec![0.0; 16])];
        assert!(NativeModel::from_named(&named).is_err());
    }

    #[test]
    fn continuous_input_path() {
        let model = NativeModel::init_random(&NativeInit {
            kind: "minlstm".to_string(),
            n_layers: 1,
            d_model: 6,
            expansion: 1,
            vocab_in: None,
            input_dim: Some(4),
            vocab_out: 2,
            conv: false,
            mlp: false,
            mlp_mult: 4,
            forget_bias: 1.0,
            max_len: 16,
            n_heads: 2,
        }, 9).unwrap();
        let x = Tensor::f32(vec![2, 3, 4], vec![0.1; 24]);
        let (logits, state) = model.forward(&x).unwrap();
        assert_eq!(logits.dims, vec![2, 3, 2]);
        let xt = Tensor::f32(vec![2, 4], vec![0.2; 8]);
        let (l2, _) = model.step(&xt, state).unwrap();
        assert_eq!(l2.dims, vec![2, 2]);
    }

    #[test]
    fn unknown_kind_error_lists_accepted_values() {
        let err = NativeModel::init_random(&NativeInit {
            kind: "mamba9000".to_string(),
            ..NativeInit::default()
        }, 1).unwrap_err();
        let msg = format!("{err:#}");
        for kind in super::super::mixer::MIXER_KINDS {
            assert!(msg.contains(kind),
                    "error should list '{kind}': {msg}");
        }
    }

    #[test]
    fn rejects_mixed_kind_checkpoints() {
        // splice block 0 of a minGRU model into a minLSTM model's tensors
        let gru = tiny_model("mingru", false, false);
        let lstm = tiny_model("minlstm", false, false);
        let mut named: Vec<NamedTensor> = lstm.to_named().into_iter()
            .filter(|t| !t.name.starts_with("params/blocks/0/mixer/"))
            .collect();
        named.extend(gru.to_named().into_iter()
            .filter(|t| t.name.starts_with("params/blocks/0/mixer/")));
        let err = NativeModel::from_named(&named).unwrap_err();
        assert!(format!("{err:#}").contains("mixed mixer kinds"),
                "got: {err:#}");
    }

    #[test]
    fn transformer_lane_roundtrip_is_bit_exact() {
        // export mid-stream, import into a fresh state, decode both:
        // the KV ring + lane_pos header must reproduce decode exactly
        let model = tiny_model("transformer", true, false);
        let (batch, t) = (2usize, 5usize);
        let x = Tensor::i32(vec![batch, t],
                            (0..batch * t).map(|i| (i % 11) as i32)
                                .collect());
        let (_, state) = model.forward(&x).unwrap();
        assert!(model.lane_state_bytes() >= 4 + 2 * 16 * 8 * 4 * 2,
                "KV lane export should be O(max_len)");
        let snap = model.export_lane(&state, 1).unwrap();
        assert_eq!(snap.len(), model.lane_state_bytes());

        let mut fresh = model.init_state(batch);
        model.import_lane(&mut fresh, 0, &snap).unwrap();
        // lane 0 of `fresh` now mirrors lane 1 of `state`
        let xt = Tensor::i32(vec![batch], vec![7, 7]);
        let (la, _) = model.step(&xt, state).unwrap();
        let (lb, _) = model.step(&xt, fresh).unwrap();
        let (av, bv) = (la.data.as_f32().unwrap(),
                        lb.data.as_f32().unwrap());
        assert_eq!(&av[11..22], &bv[0..11],
                   "imported lane drifted from the exported one");
    }
}
