//! Inference backends.  The [`crate::runtime::Backend`] trait abstracts
//! "something that can prefill a context and decode tokens"; this module
//! provides the **native** pure-Rust CPU implementation (module
//! [`native`]), while the PJRT/XLA artifact implementation lives in
//! [`crate::runtime::backend::PjrtBackend`].
//!
//! The native backend exists so that serving, testing, and the examples
//! can run end-to-end with zero Python/XLA dependencies: it loads the same
//! MRNN checkpoints the PJRT trainer writes (`util::io`), implements the
//! log-space scan + sequential decode of the paper, and plugs into
//! `coordinator::infer::generate` / `coordinator::server::serve` through
//! the same trait as the artifact runtime.

pub mod native;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::backend::SessionState;
use crate::runtime::Backend;
use crate::tensor::Tensor;

pub use native::{kinds_help, Head, Mixer, NativeInit, NativeModel,
                 NativeScratch, NativeState, NativeTrainer, MIXER_KINDS};

/// Native CPU backend: owns the model parameters, serves any batch size.
pub struct NativeBackend {
    pub model: NativeModel,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model }
    }

    /// Load from an MRNN checkpoint (as written by the PJRT trainer or
    /// [`NativeModel::to_named`] + `util::io::save`).
    pub fn from_checkpoint(path: &Path) -> Result<NativeBackend> {
        Ok(NativeBackend { model: NativeModel::from_checkpoint(path)? })
    }
}

impl Backend for NativeBackend {
    type State = NativeState;

    fn name(&self) -> &str {
        "native"
    }

    fn step_batches(&self) -> Vec<usize> {
        Vec::new() // any batch size works
    }

    fn decode_state(&self, batch: usize) -> Result<NativeState> {
        Ok(self.model.init_state(batch))
    }

    fn decode_step(&self, x_t: &Tensor, state: NativeState)
                   -> Result<(Tensor, NativeState)> {
        self.model.step(x_t, state)
    }

    fn prefill(&self, x: &Tensor) -> Result<(Tensor, NativeState)> {
        self.model.prefill(x)
    }

    /// Native lane reset enables continuous batching in the serving loop.
    fn reset_lane(&self, state: &mut NativeState, lane: usize) -> bool {
        self.model.reset_lane(state, lane).is_ok()
    }

    /// The native state is plain host data, so lanes re-seed in place —
    /// this is what opts the backend into mid-decode admission in
    /// `coordinator::scheduler`.
    fn lane_reset_supported(&self) -> bool {
        true
    }

    /// Host-side f32 state: per-lane export/import is supported, which
    /// opts the backend into the coordinator's session cache.
    fn state_fingerprint(&self) -> Option<u64> {
        Some(self.model.state_fingerprint())
    }

    fn export_state(&self, state: &NativeState, lane: usize)
                    -> Result<SessionState> {
        Ok(SessionState {
            fingerprint: self.model.state_fingerprint(),
            bytes: self.model.export_lane(state, lane)?,
        })
    }

    fn import_state(&self, state: &mut NativeState, lane: usize,
                    snap: &SessionState) -> Result<()> {
        let want = self.model.state_fingerprint();
        if snap.fingerprint != want {
            bail!("session state fingerprint {:#018x} does not match \
                   this model's decode-state layout ({want:#018x}); the \
                   snapshot was exported from a different architecture",
                  snap.fingerprint);
        }
        self.model.import_lane(state, lane, &snap.bytes)
    }
}
