//! Parallelism properties of the native backend:
//!
//! * the threaded tiled GEMM (`Dense::apply`) and the threaded chunked
//!   log-space scan (`scan_log`) are **bit-for-bit** identical across
//!   thread counts {1, 2, 7} and against their sequential/naive
//!   references, including odd shapes that don't divide evenly into the
//!   kernels' row/column/channel blocks;
//! * lockstep-batched (continuous-batching) serving produces exactly the
//!   tokens per-request sequential decode produces.
//!
//! Bit-exactness holds by construction — task granularity is a fixed
//! constant of each kernel and per-element operation order never depends
//! on blocking or thread count — and these tests keep it that way.

use minrnn::backend::native::linalg::Dense;
use minrnn::backend::native::scan::{scan_linear, scan_linear_pool,
                                    scan_log, scan_log_pool};
use minrnn::backend::native::MixerScratch;
use minrnn::backend::{Mixer, NativeBackend, NativeInit, NativeModel,
                      MIXER_KINDS};
use minrnn::coordinator::{infer, server};
use minrnn::util::rng::Rng;
use minrnn::util::threads::ThreadPool;

const THREAD_COUNTS: [usize; 3] = [1, 2, 7];

fn naive_dense(d: &Dense, x: &[f32], rows: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * d.d_out];
    for r in 0..rows {
        for o in 0..d.d_out {
            let mut acc = d.b[o];
            for k in 0..d.d_in {
                acc += x[r * d.d_in + k] * d.w[k * d.d_out + o];
            }
            y[r * d.d_out + o] = acc;
        }
    }
    y
}

#[test]
fn prop_dense_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(0xD15E);
    let pools: Vec<ThreadPool> =
        THREAD_COUNTS.iter().map(|&n| ThreadPool::new(n)).collect();
    // odd shapes straddling N_TILE (16), ROW_BLOCK (32), COL_BLOCK (64)
    for &(rows, d_in, d_out) in &[(1usize, 3usize, 5usize), (7, 17, 23),
                                  (33, 16, 16), (64, 8, 130), (65, 13, 31),
                                  (2, 96, 257), (129, 7, 65)] {
        let dense = Dense::new(
            d_in, d_out,
            (0..d_in * d_out).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            (0..d_out).map(|_| rng.normal_f32(0.0, 0.3)).collect()).unwrap();
        let x: Vec<f32> = (0..rows * d_in)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = naive_dense(&dense, &x, rows);
        for (pool, &n) in pools.iter().zip(&THREAD_COUNTS) {
            let got = dense.apply_pool(pool, &x, rows);
            assert_eq!(got, want,
                       "Dense {rows}x{d_in}x{d_out} differs on {n} threads");
        }
    }
}

#[test]
fn prop_scan_log_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(0x5CA9);
    let pools: Vec<ThreadPool> =
        THREAD_COUNTS.iter().map(|&n| ThreadPool::new(n)).collect();
    // shapes straddling TIME_CHUNK (64) and D_BLOCK (32)
    for &(b, t, d) in &[(1usize, 1usize, 1usize), (2, 7, 3), (1, 65, 31),
                        (3, 130, 33), (2, 64, 16), (1, 311, 5)] {
        let n = b * t * d;
        let la: Vec<f32> = (0..n).map(|_| rng.range_f32(-7.0, 0.0))
            .collect();
        let lb: Vec<f32> = (0..n).map(|_| rng.range_f32(-7.0, 1.5))
            .collect();
        let lh0: Vec<f32> = (0..b * d).map(|_| rng.range_f32(-2.0, 0.5))
            .collect();
        // the sequential reference: the same kernel on a 1-thread pool
        let want = scan_log_pool(&pools[0], &la, &lb, &lh0, b, t, d);
        for (pool, &nthr) in pools.iter().zip(&THREAD_COUNTS).skip(1) {
            let got = scan_log_pool(pool, &la, &lb, &lh0, b, t, d);
            assert_eq!(got, want,
                       "scan_log ({b},{t},{d}) differs on {nthr} threads");
        }
        // and the global-pool entry point agrees bit-for-bit too
        assert_eq!(scan_log(&la, &lb, &lh0, b, t, d), want);
    }
}

#[test]
fn prop_scan_linear_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(0x11EA);
    let pools: Vec<ThreadPool> =
        THREAD_COUNTS.iter().map(|&n| ThreadPool::new(n)).collect();
    for &(b, t, d) in &[(1usize, 9usize, 33usize), (2, 130, 7),
                        (3, 65, 32)] {
        let n = b * t * d;
        let a: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let bb: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let h0: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let want = scan_linear_pool(&pools[0], &a, &bb, &h0, b, t, d);
        for pool in pools.iter().skip(1) {
            assert_eq!(scan_linear_pool(pool, &a, &bb, &h0, b, t, d), want);
        }
        assert_eq!(scan_linear(&a, &bb, &h0, b, t, d), want);
    }
}

// ---------------------------------------------------------------------------
// every mixer kind is bit-exact across thread counts (prefill + decode)
// ---------------------------------------------------------------------------

#[test]
fn prop_mixer_kinds_bit_exact_across_thread_counts() {
    // big enough that batch*heads*t*t*hd and the gate chunking exceed the
    // kernels' inline thresholds, so the pooled paths actually run
    let (batch, t, d) = (4usize, 40usize, 32usize);
    let pools: Vec<ThreadPool> =
        THREAD_COUNTS.iter().map(|&n| ThreadPool::new(n)).collect();
    for &kind in MIXER_KINDS {
        let model = NativeModel::init_random(&NativeInit {
            kind: kind.to_string(),
            n_layers: 1,
            d_model: d,
            expansion: 2,
            vocab_in: Some(24),
            vocab_out: 24,
            max_len: 64,
            n_heads: 4,
            ..NativeInit::default()
        }, 0xB17).unwrap();
        let mixer = model.blocks[0].mixer.m();
        let sl = mixer.state_len();
        let mut rng = Rng::new(0x5EED);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // prefill: outputs AND final mixer state identical on every pool
        let mut want_y: Option<Vec<f32>> = None;
        let mut want_state: Option<Vec<f32>> = None;
        for (pool, &n) in pools.iter().zip(&THREAD_COUNTS) {
            let mut ms = MixerScratch::default();
            let mut y = Vec::new();
            let mut state = vec![0.0f32; batch * sl];
            for lane in state.chunks_mut(sl.max(1)) {
                mixer.init_lane(lane);
            }
            mixer.parallel_into(pool, &x, batch, t, &mut ms, &mut y,
                                &mut state).unwrap();
            match (&want_y, &want_state) {
                (None, _) => {
                    want_y = Some(y);
                    want_state = Some(state);
                }
                (Some(wy), Some(ws)) => {
                    assert_eq!(&y, wy,
                               "{kind} prefill differs on {n} threads");
                    assert_eq!(&state, ws,
                               "{kind} state differs on {n} threads");
                }
                _ => unreachable!(),
            }
        }

        // decode: every step's output identical on every pool
        let mut states: Vec<Vec<f32>> = pools.iter()
            .map(|_| {
                let mut s = vec![0.0f32; batch * sl];
                for lane in s.chunks_mut(sl.max(1)) {
                    mixer.init_lane(lane);
                }
                s
            }).collect();
        let mut scratch: Vec<MixerScratch> =
            pools.iter().map(|_| MixerScratch::default()).collect();
        for ti in 0..t {
            let mut x_t = vec![0.0f32; batch * d];
            for bi in 0..batch {
                x_t[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let pos = vec![ti as u32; batch];
            let mut want: Option<Vec<f32>> = None;
            for (pi, pool) in pools.iter().enumerate() {
                let mut y = Vec::new();
                mixer.step_into(pool, &x_t, batch, &pos, &mut states[pi],
                                &mut scratch[pi], &mut y).unwrap();
                match &want {
                    None => want = Some(y),
                    Some(w) => assert_eq!(&y, w,
                        "{kind} step {ti} differs on {} threads",
                        THREAD_COUNTS[pi]),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// batched lockstep serving == per-request sequential decode
// ---------------------------------------------------------------------------

fn serving_model(kind: &str) -> NativeModel {
    NativeModel::init_random(&NativeInit {
        kind: kind.to_string(),
        n_layers: 2,
        d_model: 16,
        expansion: 2,
        vocab_in: Some(24),
        input_dim: None,
        vocab_out: 24,
        conv: true,  // exercises conv ring-buffer lane reset
        mlp: true,
        mlp_mult: 2,
        forget_bias: 0.5,
        max_len: 32, // covers the longest prompt + decode below
        n_heads: 4,
    }, 0xFACE).unwrap()
}

#[test]
fn prop_batched_lockstep_decode_matches_sequential() {
    for &kind in MIXER_KINDS {
        let backend = NativeBackend::new(serving_model(kind));
        let mut rng = Rng::new(77);
        let requests: Vec<server::Request> = (0..7).map(|i| {
            server::Request {
                id: i,
                prompt: (0..1 + rng.usize_below(5))
                    .map(|_| rng.below(24) as i32).collect(),
                n_tokens: 3 + rng.usize_below(5),
                session: None,
            }
        }).collect();

        // greedy (temperature 0) makes sampling deterministic, so the
        // batched run must reproduce sequential decode token-for-token
        let mut want = Vec::new();
        for req in &requests {
            let mut r = Rng::new(0);
            want.push(infer::generate(&backend, &req.prompt, req.n_tokens,
                                      0.0, &mut r).unwrap());
        }

        // max_batch 3 < 7 requests forces continuous lane refill, so this
        // also pins that a re-seeded lane starts from a truly fresh state
        let stats = server::serve_opts(&backend, requests.clone(),
                                       &server::ServeOpts {
                                           temperature: 0.0,
                                           seed: 5,
                                           max_batch: 3,
                                       }).unwrap();
        assert_eq!(stats.responses.len(), requests.len());
        for resp in &stats.responses {
            let idx = resp.id as usize;
            assert_eq!(resp.tokens, want[idx],
                       "{kind}: request {idx} diverged between batched \
                        and sequential decode");
        }
    }
}
