//! Property-based tests (util::prop) over the substrates and the data
//! layer: round-trips, invariants and oracles under random inputs.

use minrnn::data::chomsky;
use minrnn::data::lra::listops;
use minrnn::util::json::{self, Json};
use minrnn::util::prop::{check, i64_range, vec_of, Gen};
use minrnn::util::rng::Rng;
use minrnn::util::stats;
use minrnn::util::io::{self, NamedTensor};

#[test]
fn prop_json_roundtrip_arbitrary_numbers() {
    let gen = vec_of(i64_range(-1_000_000, 1_000_000), 24);
    check(&gen, |v| {
        let j = Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let text = json::to_string(&j);
        json::parse(&text).map(|p| p == j).unwrap_or(false)
    });
}

#[test]
fn prop_json_roundtrip_strings() {
    // random "hostile" strings: control chars, quotes, unicode
    let gen = Gen::new(|rng: &mut Rng, size: usize| {
        let n = rng.usize_below(size.max(1) + 1);
        (0..n).map(|_| {
            match rng.below(6) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => char::from_u32(rng.below(26) as u32 + 'a' as u32)
                    .unwrap(),
                4 => 'é',
                _ => '😀',
            }
        }).collect::<String>()
    });
    let mut rng = Rng::new(1);
    for case in 0..300 {
        let s = gen.sample(&mut rng, 4 + case / 4);
        let j = Json::Str(s.clone());
        let parsed = json::parse(&json::to_string(&j)).unwrap();
        assert_eq!(parsed.as_str(), Some(s.as_str()));
    }
}

#[test]
fn prop_checkpoint_roundtrip() {
    let mut rng = Rng::new(2);
    let dir = std::env::temp_dir().join("minrnn_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..30 {
        let n_tensors = 1 + rng.usize_below(5);
        let tensors: Vec<NamedTensor> = (0..n_tensors).map(|i| {
            let d0 = 1 + rng.usize_below(6);
            let d1 = 1 + rng.usize_below(6);
            if rng.bool(0.5) {
                NamedTensor::f32(&format!("t{i}"), vec![d0, d1],
                                 (0..d0 * d1)
                                 .map(|_| rng.normal_f32(0.0, 10.0))
                                 .collect())
            } else {
                NamedTensor::i32(&format!("t{i}"), vec![d0, d1],
                                 (0..d0 * d1)
                                 .map(|_| rng.below(1000) as i32 - 500)
                                 .collect())
            }
        }).collect();
        let path = dir.join(format!("c{case}.bin"));
        io::save(&path, &tensors).unwrap();
        assert_eq!(io::load(&path).unwrap(), tensors);
    }
}

#[test]
fn prop_percentile_bounded_by_extremes() {
    let gen = vec_of(i64_range(-1000, 1000), 40);
    check(&gen, |v| {
        if v.is_empty() {
            return true;
        }
        let xs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        [0.0, 25.0, 50.0, 99.0, 100.0].iter().all(|&q| {
            let p = stats::percentile(&xs, q);
            p >= lo - 1e-9 && p <= hi + 1e-9
        })
    });
}

#[test]
fn prop_welford_equals_batch_stats() {
    let gen = vec_of(i64_range(-500, 500), 64);
    check(&gen, |v| {
        if v.len() < 2 {
            return true;
        }
        let xs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let mut w = stats::Welford::new();
        for &x in &xs {
            w.push(x);
        }
        (w.mean() - stats::mean(&xs)).abs() < 1e-9
            && (w.std() - stats::std(&xs)).abs() < 1e-9
    });
}

#[test]
fn prop_listops_eval_matches_bruteforce() {
    // independent reference evaluator over the token stream
    fn eval_tokens(tokens: &[i32], pos: &mut usize) -> i64 {
        let t = tokens[*pos];
        *pos += 1;
        if (2..=11).contains(&t) {
            return (t - 2) as i64;
        }
        assert_eq!(t, listops::OPEN);
        let op = tokens[*pos];
        *pos += 1;
        let mut vals = Vec::new();
        while tokens[*pos] != listops::CLOSE {
            vals.push(eval_tokens(tokens, pos));
        }
        *pos += 1;
        match op {
            listops::OP_MAX => *vals.iter().max().unwrap(),
            listops::OP_MIN => *vals.iter().min().unwrap(),
            listops::OP_MED => {
                vals.sort_unstable();
                vals[vals.len() / 2]
            }
            listops::OP_SM => vals.iter().sum::<i64>().rem_euclid(10),
            _ => panic!("bad op"),
        }
    }

    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let (tokens, label) = listops::sample(&mut rng, 100);
        let mut pos = 0;
        let value = eval_tokens(&tokens, &mut pos);
        assert_eq!(pos, tokens.len(), "evaluator must consume everything");
        assert_eq!(value, label as i64);
    }
}

#[test]
fn prop_chomsky_total_len_consistent() {
    let mut rng = Rng::new(4);
    for task in chomsky::all_tasks() {
        for _ in 0..40 {
            let n = 1 + rng.usize_below(40);
            let ex = task.sample(&mut rng, n);
            assert_eq!(ex.input.len(), task.total_len(n),
                       "{} total_len mismatch at n={n}", task.name());
        }
    }
}

#[test]
fn prop_rng_below_never_exceeds() {
    let gen = i64_range(1, 1_000_000);
    check(&gen, |&n| {
        let mut rng = Rng::new(n as u64);
        (0..100).all(|_| rng.below(n as u64) < n as u64)
    });
}
