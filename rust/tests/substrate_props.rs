//! Property-based tests (util::prop) over the substrates and the data
//! layer: round-trips, invariants and oracles under random inputs —
//! including the native backend's log-space scan against the naive
//! sequential recurrence and its `a_t → 0/1` edge cases.

use minrnn::backend::native::scan::{scan_linear, scan_log, scan_log_seq,
                                    LOG_ZERO};
use minrnn::data::chomsky;
use minrnn::data::lra::listops;
use minrnn::util::json::{self, Json};
use minrnn::util::prop::{check, i64_range, vec_of, Gen};
use minrnn::util::rng::Rng;
use minrnn::util::stats;
use minrnn::util::io::{self, NamedTensor};

#[test]
fn prop_json_roundtrip_arbitrary_numbers() {
    let gen = vec_of(i64_range(-1_000_000, 1_000_000), 24);
    check(&gen, |v| {
        let j = Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let text = json::to_string(&j);
        json::parse(&text).map(|p| p == j).unwrap_or(false)
    });
}

#[test]
fn prop_json_roundtrip_strings() {
    // random "hostile" strings: control chars, quotes, unicode
    let gen = Gen::new(|rng: &mut Rng, size: usize| {
        let n = rng.usize_below(size.max(1) + 1);
        (0..n).map(|_| {
            match rng.below(6) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => char::from_u32(rng.below(26) as u32 + 'a' as u32)
                    .unwrap(),
                4 => 'é',
                _ => '😀',
            }
        }).collect::<String>()
    });
    let mut rng = Rng::new(1);
    for case in 0..300 {
        let s = gen.sample(&mut rng, 4 + case / 4);
        let j = Json::Str(s.clone());
        let parsed = json::parse(&json::to_string(&j)).unwrap();
        assert_eq!(parsed.as_str(), Some(s.as_str()));
    }
}

#[test]
fn prop_checkpoint_roundtrip() {
    let mut rng = Rng::new(2);
    let dir = std::env::temp_dir().join("minrnn_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..30 {
        let n_tensors = 1 + rng.usize_below(5);
        let tensors: Vec<NamedTensor> = (0..n_tensors).map(|i| {
            let d0 = 1 + rng.usize_below(6);
            let d1 = 1 + rng.usize_below(6);
            if rng.bool(0.5) {
                NamedTensor::f32(&format!("t{i}"), vec![d0, d1],
                                 (0..d0 * d1)
                                 .map(|_| rng.normal_f32(0.0, 10.0))
                                 .collect())
            } else {
                NamedTensor::i32(&format!("t{i}"), vec![d0, d1],
                                 (0..d0 * d1)
                                 .map(|_| rng.below(1000) as i32 - 500)
                                 .collect())
            }
        }).collect();
        let path = dir.join(format!("c{case}.bin"));
        io::save(&path, &tensors).unwrap();
        assert_eq!(io::load(&path).unwrap(), tensors);
    }
}

#[test]
fn prop_percentile_bounded_by_extremes() {
    let gen = vec_of(i64_range(-1000, 1000), 40);
    check(&gen, |v| {
        if v.is_empty() {
            return true;
        }
        let xs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        [0.0, 25.0, 50.0, 99.0, 100.0].iter().all(|&q| {
            let p = stats::percentile(&xs, q);
            p >= lo - 1e-9 && p <= hi + 1e-9
        })
    });
}

#[test]
fn prop_welford_equals_batch_stats() {
    let gen = vec_of(i64_range(-500, 500), 64);
    check(&gen, |v| {
        if v.len() < 2 {
            return true;
        }
        let xs: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let mut w = stats::Welford::new();
        for &x in &xs {
            w.push(x);
        }
        (w.mean() - stats::mean(&xs)).abs() < 1e-9
            && (w.std() - stats::std(&xs)).abs() < 1e-9
    });
}

#[test]
fn prop_listops_eval_matches_bruteforce() {
    // independent reference evaluator over the token stream
    fn eval_tokens(tokens: &[i32], pos: &mut usize) -> i64 {
        let t = tokens[*pos];
        *pos += 1;
        if (2..=11).contains(&t) {
            return (t - 2) as i64;
        }
        assert_eq!(t, listops::OPEN);
        let op = tokens[*pos];
        *pos += 1;
        let mut vals = Vec::new();
        while tokens[*pos] != listops::CLOSE {
            vals.push(eval_tokens(tokens, pos));
        }
        *pos += 1;
        match op {
            listops::OP_MAX => *vals.iter().max().unwrap(),
            listops::OP_MIN => *vals.iter().min().unwrap(),
            listops::OP_MED => {
                vals.sort_unstable();
                vals[vals.len() / 2]
            }
            listops::OP_SM => vals.iter().sum::<i64>().rem_euclid(10),
            _ => panic!("bad op"),
        }
    }

    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let (tokens, label) = listops::sample(&mut rng, 100);
        let mut pos = 0;
        let value = eval_tokens(&tokens, &mut pos);
        assert_eq!(pos, tokens.len(), "evaluator must consume everything");
        assert_eq!(value, label as i64);
    }
}

#[test]
fn prop_chomsky_total_len_consistent() {
    let mut rng = Rng::new(4);
    for task in chomsky::all_tasks() {
        for _ in 0..40 {
            let n = 1 + rng.usize_below(40);
            let ex = task.sample(&mut rng, n);
            assert_eq!(ex.input.len(), task.total_len(n),
                       "{} total_len mismatch at n={n}", task.name());
        }
    }
}

#[test]
fn prop_rng_below_never_exceeds() {
    let gen = i64_range(1, 1_000_000);
    check(&gen, |&n| {
        let mut rng = Rng::new(n as u64);
        (0..100).all(|_| rng.below(n as u64) < n as u64)
    });
}

// ---------------------------------------------------------------------------
// native log-space scan: oracle agreement, h0 propagation, gate edge cases
// ---------------------------------------------------------------------------

/// f64 oracle: `v_t = a_t * v_{t-1} + b_t` evaluated directly.
fn naive_recurrence(a: &[f32], b: &[f32], h0: &[f32], batch: usize,
                    t: usize, d: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; batch * t * d];
    for bi in 0..batch {
        for di in 0..d {
            let mut v = h0[bi * d + di] as f64;
            for ti in 0..t {
                let off = (bi * t + ti) * d + di;
                v = a[off] as f64 * v + b[off] as f64;
                out[off] = v;
            }
        }
    }
    out
}

#[test]
fn prop_native_scan_log_agrees_with_naive_recurrence() {
    // random positive (a, b, h0) across random shapes, both scan forms
    let mut rng = Rng::new(0xA11CE);
    for case in 0..60 {
        let batch = 1 + rng.usize_below(3);
        let t = 1 + rng.usize_below(if case % 5 == 0 { 200 } else { 24 });
        let d = 1 + rng.usize_below(4);
        let n = batch * t * d;
        let la: Vec<f32> = (0..n).map(|_| rng.range_f32(-6.0, 0.0))
            .collect();
        let lb: Vec<f32> = (0..n).map(|_| rng.range_f32(-6.0, 1.5))
            .collect();
        let lh0: Vec<f32> = (0..batch * d)
            .map(|_| rng.range_f32(-3.0, 1.0)).collect();
        let a: Vec<f32> = la.iter().map(|&x| x.exp()).collect();
        let b: Vec<f32> = lb.iter().map(|&x| x.exp()).collect();
        let h0: Vec<f32> = lh0.iter().map(|&x| x.exp()).collect();
        let oracle = naive_recurrence(&a, &b, &h0, batch, t, d);
        let chunked = scan_log(&la, &lb, &lh0, batch, t, d);
        let seq = scan_log_seq(&la, &lb, &lh0, batch, t, d);
        for i in 0..n {
            let tol = 2e-4 * oracle[i].abs().max(1.0);
            assert!((chunked[i] as f64 - oracle[i]).abs() < tol,
                    "case {case} chunked[{i}]: {} vs {}", chunked[i],
                    oracle[i]);
            assert!((seq[i] as f64 - oracle[i]).abs() < tol,
                    "case {case} seq[{i}]: {} vs {}", seq[i], oracle[i]);
        }
    }
}

#[test]
fn prop_native_scan_propagates_h0() {
    // a_t = 1, b_t = 0: the state must stay exactly h0 forever — this is
    // what carries prefill state into decode across chunk boundaries
    let mut rng = Rng::new(0xB0B);
    for _ in 0..20 {
        let batch = 1 + rng.usize_below(2);
        let t = 1 + rng.usize_below(300);
        let d = 1 + rng.usize_below(3);
        let n = batch * t * d;
        let la = vec![0.0f32; n];           // log 1
        let lb = vec![LOG_ZERO; n];         // log 0
        let lh0: Vec<f32> = (0..batch * d)
            .map(|_| rng.range_f32(-2.0, 1.0)).collect();
        let h = scan_log(&la, &lb, &lh0, batch, t, d);
        for bi in 0..batch {
            for ti in 0..t {
                for di in 0..d {
                    let want = lh0[bi * d + di].exp();
                    let got = h[(bi * t + ti) * d + di];
                    assert!((got - want).abs() < 1e-5 * want.max(1.0),
                            "h0 not propagated at t={ti}: {got} vs {want}");
                }
            }
        }
    }
}

#[test]
fn prop_native_scan_gate_edge_cases() {
    let mut rng = Rng::new(0xED6E);
    let (batch, t, d) = (2usize, 130usize, 2usize);
    let n = batch * t * d;

    // a_t → 0 (gate fully open): h_t ≈ b_t, history forgotten instantly
    let la = vec![-40.0f32; n]; // a = e^-40 ≈ 0 in f32
    let lb: Vec<f32> = (0..n).map(|_| rng.range_f32(-3.0, 2.0)).collect();
    let lh0: Vec<f32> = (0..batch * d).map(|_| rng.range_f32(-1.0, 1.0))
        .collect();
    let h = scan_log(&la, &lb, &lh0, batch, t, d);
    for i in 0..n {
        let want = lb[i].exp();
        assert!((h[i] - want).abs() < 1e-5 * want.max(1.0),
                "a→0: h[{i}] = {} vs b = {want}", h[i]);
        assert!(h[i].is_finite());
    }

    // a_t → 1 with tiny b: long-horizon stability — the state decays
    // monotonically toward the accumulated b sum, never NaN/inf
    let la1 = vec![-1e-6f32; n]; // a ≈ 1
    let lb1 = vec![-30.0f32; n]; // b ≈ 1e-13
    let lh01 = vec![0.5f32.ln(); batch * d];
    let h1 = scan_log(&la1, &lb1, &lh01, batch, t, d);
    for (i, &v) in h1.iter().enumerate() {
        assert!(v.is_finite(), "a→1: non-finite at {i}");
        assert!((v - 0.5).abs() < 1e-3, "a→1: drifted to {v} at {i}");
    }

    // mixed saturated gates stay finite and non-negative
    let la2: Vec<f32> = (0..n).map(|_| if rng.bool(0.5) { -40.0 }
                                       else { -1e-7 }).collect();
    let lb2: Vec<f32> = (0..n).map(|_| if rng.bool(0.5) { LOG_ZERO }
                                       else { 0.0 }).collect();
    let h2 = scan_log(&la2, &lb2, &lh01, batch, t, d);
    assert!(h2.iter().all(|v| v.is_finite() && *v >= 0.0));
}

#[test]
fn prop_native_scan_linear_agrees_with_naive() {
    let mut rng = Rng::new(0x11EA8);
    for _ in 0..40 {
        let batch = 1 + rng.usize_below(3);
        let t = 1 + rng.usize_below(40);
        let d = 1 + rng.usize_below(4);
        let n = batch * t * d;
        let a: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.05, 1.05))
            .collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let h0: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let oracle = naive_recurrence(&a, &b, &h0, batch, t, d);
        let got = scan_linear(&a, &b, &h0, batch, t, d);
        for i in 0..n {
            assert!((got[i] as f64 - oracle[i]).abs()
                    < 1e-3 * oracle[i].abs().max(1.0),
                    "[{i}] {} vs {}", got[i], oracle[i]);
        }
    }
}
