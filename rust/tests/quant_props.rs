//! Properties of per-tile int8 weight quantization
//! (`backend::native::quant` + the v3 checkpoint leaf encoding):
//!
//! * **round-trip exactness** — quantize → save → load reproduces the
//!   quantized model bit for bit (logits included), and the on-disk
//!   version byte moves 2 → 3 only when int8 leaves are present (pure
//!   f32 checkpoints stay byte-compatible with older readers);
//! * **golden-error budget** — quantized logits stay within
//!   `LOGIT_REL_ERR_BUDGET` (max |q−f| / max(1, |f|), the same gate
//!   `minrnn quantize` enforces) of the f32 source on a seeded probe;
//! * **eval-loss budget** — on a trained tiny char-LM the mean-CE
//!   delta between f32 and int8 stays under
//!   `EVAL_LOSS_DELTA_BUDGET` nats;
//! * **stale sessions fail clean** — a session snapshot exported from
//!   the f32 model is refused by the quantized model with an error
//!   naming the fingerprint (quantization changes the fingerprint on
//!   purpose: cached f32 states describe a different serving model);
//! * **training is refused** — resuming a trainer from a quantized
//!   checkpoint errors, naming quantization, instead of optimizing
//!   empty weight vectors.

use std::path::PathBuf;

use minrnn::backend::native::quant;
use minrnn::backend::{NativeBackend, NativeInit, NativeModel,
                      NativeTrainer};
use minrnn::runtime::Backend;
use minrnn::tensor::{Batch, Tensor};
use minrnn::util::io;
use minrnn::util::rng::Rng;

const VOCAB: usize = 16;

fn tiny_lm(seed: u64) -> NativeModel {
    NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 2,
        d_model: 16,
        expansion: 2,
        vocab_in: Some(VOCAB),
        input_dim: None,
        vocab_out: VOCAB,
        conv: true,
        mlp: true,
        mlp_mult: 2,
        forget_bias: 0.5,
        ..NativeInit::default()
    }, seed).unwrap()
}

/// Identity-task batch: predict the current token — learnable through
/// the residual path in a handful of steps, which is all the loss-delta
/// property needs.
fn identity_batch(rng: &mut Rng, b: usize, t: usize) -> Batch {
    let toks: Vec<i32> = (0..b * t)
        .map(|_| rng.below(VOCAB as u64) as i32).collect();
    Batch {
        x: Tensor::i32(vec![b, t], toks.clone()),
        targets: Tensor::i32(vec![b, t], toks),
        mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
    }
}

/// Mean cross-entropy of all-position logits against `targets` —
/// computed the same way for the f32 and the quantized model, so the
/// delta isolates quantization.
fn mean_ce(model: &NativeModel, x: &Tensor, targets: &[i32]) -> f32 {
    let (logits, _) = model.forward(x).unwrap();
    let lv = logits.data.as_f32().unwrap();
    let v = model.vocab_out;
    let rows = lv.len() / v;
    assert_eq!(rows, targets.len());
    let mut total = 0.0f64;
    for r in 0..rows {
        let row = &lv[r * v..(r + 1) * v];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter()
            .map(|&z| ((z - m) as f64).exp()).sum::<f64>().ln()
            + m as f64;
        total += lse - row[targets[r] as usize] as f64;
    }
    (total / rows as f64) as f32
}

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("minrnn_quant_props");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The MRNN header version field: magic (4 bytes) then a LE u32.
fn ckpt_version(path: &std::path::Path) -> u32 {
    let bytes = std::fs::read(path).unwrap();
    assert_eq!(&bytes[..4], b"MRNN");
    u32::from_le_bytes(bytes[4..8].try_into().unwrap())
}

// ---------------------------------------------------------------------------
// quantize → save → load round-trip + version stamping
// ---------------------------------------------------------------------------

#[test]
fn quantized_checkpoints_roundtrip_exactly_and_stamp_v3() {
    let model = tiny_lm(0xABC);
    let f32_path = tmp_path("roundtrip_f32.ckpt");
    io::save(&f32_path, &model.to_named()).unwrap();
    assert_eq!(ckpt_version(&f32_path), io::VERSION_F32,
               "pure-f32 checkpoints must keep the v2 encoding");

    let mut qm = model.clone();
    quant::quantize_model(&mut qm).unwrap();
    let q_path = tmp_path("roundtrip_int8.ckpt");
    io::save(&q_path, &qm.to_named()).unwrap();
    assert_eq!(ckpt_version(&q_path), io::VERSION,
               "int8 leaves must bump the container version");
    assert!(std::fs::metadata(&q_path).unwrap().len()
            < std::fs::metadata(&f32_path).unwrap().len(),
            "the int8 checkpoint must be smaller than its f32 source");

    let back = NativeModel::from_checkpoint(&q_path).unwrap();
    assert!(back.is_quantized());
    assert_eq!(back.state_fingerprint(), qm.state_fingerprint());
    let x = quant::probe_input(&model, 2, 16, 1);
    let (a, _) = qm.forward(&x).unwrap();
    let (b, _) = back.forward(&x).unwrap();
    assert_eq!(a, b, "reloaded quantized model must match bit for bit");
}

// ---------------------------------------------------------------------------
// golden-error budget on the shared probe
// ---------------------------------------------------------------------------

#[test]
fn quantized_logits_stay_within_the_golden_error_budget() {
    let model = tiny_lm(0x60D);
    let mut qm = model.clone();
    quant::quantize_model(&mut qm).unwrap();
    let rel = quant::probe_rel_err(&model, &qm).unwrap();
    assert!(rel < quant::LOGIT_REL_ERR_BUDGET,
            "probe rel err {rel} over budget {}",
            quant::LOGIT_REL_ERR_BUDGET);
    // the budget is a ceiling, not the expectation: a tiny random-init
    // model should land an order of magnitude under it
    assert!(rel < quant::LOGIT_REL_ERR_BUDGET * 0.5,
            "probe rel err {rel} suspiciously close to the budget");
}

// ---------------------------------------------------------------------------
// eval-loss delta on a trained tiny char-LM
// ---------------------------------------------------------------------------

#[test]
fn eval_loss_delta_stays_within_budget_on_a_trained_lm() {
    let mut trainer = NativeTrainer::new(tiny_lm(0x7EA1), "quant-props");
    let mut rng = Rng::new(4);
    let mut last = f32::NAN;
    for step in 0..30 {
        let batch = identity_batch(&mut rng, 8, 12);
        last = trainer.train_batch(&batch, 0.01, step).unwrap().loss;
    }
    assert!(last.is_finite() && last < (VOCAB as f32).ln(),
            "tiny LM failed to learn anything (loss {last})");

    let mut qm = trainer.model.clone();
    quant::quantize_model(&mut qm).unwrap();
    let eval = identity_batch(&mut Rng::new(99), 8, 12);
    let targets = eval.targets.data.as_i32().unwrap().to_vec();
    let lf = mean_ce(&trainer.model, &eval.x, &targets);
    let lq = mean_ce(&qm, &eval.x, &targets);
    assert!((lq - lf).abs() < quant::EVAL_LOSS_DELTA_BUDGET,
            "eval CE moved {lf} -> {lq}, outside the {} nat budget",
            quant::EVAL_LOSS_DELTA_BUDGET);
}

// ---------------------------------------------------------------------------
// stale f32 session snapshots are refused cleanly
// ---------------------------------------------------------------------------

#[test]
fn f32_session_snapshots_are_stale_against_the_quantized_model() {
    let model = tiny_lm(0x5E55);
    let mut qm = model.clone();
    quant::quantize_model(&mut qm).unwrap();
    let f32_backend = NativeBackend::new(model);
    let q_backend = NativeBackend::new(qm);
    assert_ne!(f32_backend.state_fingerprint(),
               q_backend.state_fingerprint(),
               "quantization must change the serving fingerprint");

    // build some real f32 session state, snapshot it
    let mut state = f32_backend.decode_state(1).unwrap();
    for &tok in &[3i32, 7, 1] {
        let x = Tensor::i32(vec![1], vec![tok]);
        let (_, s) = f32_backend.decode_step(&x, state).unwrap();
        state = s;
    }
    let snap = f32_backend.export_state(&state, 0).unwrap();

    // the quantized model must refuse it by fingerprint, not crash —
    // and the refused state must stay usable
    let mut qstate = q_backend.decode_state(1).unwrap();
    let err = q_backend.import_state(&mut qstate, 0, &snap).unwrap_err();
    assert!(err.to_string().contains("fingerprint"),
            "unexpected error: {err}");
    let x = Tensor::i32(vec![1], vec![2]);
    let (logits, _) = q_backend.decode_step(&x, qstate).unwrap();
    assert_eq!(logits.dims, vec![1, VOCAB]);

    // its own snapshots round-trip fine
    let mut s2 = q_backend.decode_state(1).unwrap();
    let own = q_backend.export_state(&s2, 0).unwrap();
    q_backend.import_state(&mut s2, 0, &own).unwrap();
}

// ---------------------------------------------------------------------------
// the trainer refuses quantized checkpoints
// ---------------------------------------------------------------------------

#[test]
fn training_cannot_resume_from_a_quantized_checkpoint() {
    let mut qm = tiny_lm(0xBAD);
    quant::quantize_model(&mut qm).unwrap();
    let path = tmp_path("trainer_reject_int8.ckpt");
    io::save(&path, &qm.to_named()).unwrap();
    let err = NativeTrainer::from_checkpoint(&path, "reject")
        .unwrap_err().to_string();
    assert!(err.contains("quantized"), "unexpected error: {err}");
    // double-quantizing is refused too
    let err2 = quant::quantize_model(&mut qm).unwrap_err().to_string();
    assert!(err2.contains("already quantized"), "{err2}");
}
