//! Integration tests over the real AOT artifacts: the full
//! runtime → init → train → eval → decode → checkpoint path for the
//! quickstart variant.
//!
//! Gating: without the `artifacts` cargo feature these tests report
//! **ignored** (never silently passing).  With the feature they *fail*
//! when artifacts are missing — run `make artifacts` (at minimum
//! `python -m compile.aot --out ../artifacts --only quickstart`) or point
//! `MINRNN_ARTIFACTS` at the artifact directory, and build against a real
//! PJRT-capable `xla` crate (see rust/README.md).

use std::rc::Rc;

use minrnn::config::TrainConfig;
use minrnn::coordinator::server::{serve, Request};
use minrnn::coordinator::trainer::{FnSource, Trainer};
use minrnn::coordinator::{data_source_for, infer};
use minrnn::data::corpus::LmDataset;
use minrnn::runtime::backend::require_artifacts_at;
use minrnn::runtime::{artifacts_root, require_artifacts, Manifest, Model,
                      PjrtBackend, Runtime, ARTIFACTS_HELP};
use minrnn::tensor::Tensor;
use minrnn::util::rng::Rng;

fn open() -> (Runtime, Rc<Manifest>) {
    require_artifacts();
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let manifest = Rc::new(Manifest::load(&artifacts_root()).unwrap());
    (rt, manifest)
}

/// Ungated: the skip mechanism itself is part of the contract — gated
/// tests must be *ignored* (visible in the test summary), and the failure
/// message when artifacts are required but absent must name the remedy.
#[test]
fn artifact_gating_is_explicit_not_silent() {
    assert!(ARTIFACTS_HELP.contains("MINRNN_ARTIFACTS"),
            "help must name the env override");
    assert!(ARTIFACTS_HELP.contains("make artifacts"),
            "help must name the build step");
    // require_artifacts must panic (not return) when nothing is present,
    // so a feature-enabled run can never fake-pass.
    let dir = std::env::temp_dir().join("minrnn_no_artifacts_here");
    std::fs::create_dir_all(&dir).unwrap();
    let panicked = std::panic::catch_unwind(|| require_artifacts_at(&dir))
        .is_err();
    assert!(panicked, "require_artifacts must fail loudly, not skip");
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn manifest_loads_and_quickstart_present() {
    let (_rt, manifest) = open();
    let v = manifest.variant("quickstart").unwrap();
    assert_eq!(v.task, "masked_ce");
    assert!(v.n_params() > 0);
    assert!(v.train_file.is_some());
    assert!(!v.eval_files.is_empty());
    assert!(!v.step_files.is_empty());
    assert!(!v.prefill_files.is_empty());
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn init_is_deterministic_and_seed_sensitive() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let a = model.init(1, 0.0).unwrap();
    let b = model.init(1, 0.0).unwrap();
    let c = model.init(2, 0.0).unwrap();
    // compare a weight leaf (biases are zero regardless of seed)
    let wi = model.variant.params.iter()
        .position(|s| s.name.ends_with("/w") && s.shape.len() == 2)
        .expect("no weight leaf");
    let head_a = Tensor::from_literal(&a.params[wi]).unwrap();
    let head_b = Tensor::from_literal(&b.params[wi]).unwrap();
    let head_c = Tensor::from_literal(&c.params[wi]).unwrap();
    assert_eq!(head_a, head_b, "same seed must give same params");
    assert_ne!(head_a, head_c, "different seed must give different params");
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn training_reduces_loss_and_is_reproducible() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let run = |seed: u64| {
        let mut state = model.init(seed as i32, 0.0).unwrap();
        let mut data = data_source_for(&model.variant).unwrap();
        let cfg = TrainConfig {
            steps: 20,
            lr: 2e-3,
            eval_every: 0,
            log_every: 100,
            seed,
            ..Default::default()
        };
        let trainer = Trainer::new(&model, cfg);
        let report = trainer.run(&mut state, data.as_mut()).unwrap();
        (report.loss_curve[0].1, report.final_loss)
    };
    let (first, last) = run(0);
    assert!(last < first, "loss should drop: {first} → {last}");
    let (first2, last2) = run(0);
    assert_eq!(first, first2, "training must be reproducible");
    assert_eq!(last, last2);
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn eval_metrics_sane() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let state = model.init(0, 0.0).unwrap();
    let ds = LmDataset::synthetic(20_000, 0);
    let mut rng = Rng::new(0);
    let batch = ds.batch(&mut rng, 4, 64);
    let m = model.eval(&state, &batch).unwrap();
    // untrained 64-vocab: loss ≈ ln(64) ≈ 4.16
    assert!(m.loss > 2.0 && m.loss < 8.0, "loss {}", m.loss);
    assert!((0.0..=1.0).contains(&m.token_acc));
    assert!((0.0..=1.0).contains(&m.seq_acc));
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn decode_matches_prefill_state_shapes_and_generates() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let state = model.init(0, 0.0).unwrap();

    // prefill then continue decoding from the prefilled state
    let mut rng = Rng::new(1);
    let tokens: Vec<i32> = (0..4 * 64).map(|_| rng.below(64) as i32)
        .collect();
    let x = Tensor::i32(vec![4, 64], tokens.clone());
    let (last_logits, pstate) = model.prefill(&state.params, &x).unwrap();
    assert_eq!(last_logits.dims, vec![4, 64]);

    let x_t = Tensor::i32(vec![4], tokens[..4].to_vec());
    let (logits, _next) = model.decode_step(&state.params, &x_t, pstate)
        .unwrap();
    assert_eq!(logits.dims, vec![4, 64]);

    // free generation runs and stays in-vocab
    let backend = PjrtBackend::new(&model, &state.params);
    let out = infer::generate(&backend, &[1, 2, 3], 16, 1.0, &mut rng)
        .unwrap();
    assert_eq!(out.len(), 16);
    assert!(out.iter().all(|&t| (0..64).contains(&t)));
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn decode_parallel_sequential_equivalence() {
    // The paper's core identity: parallel-mode (prefill) and
    // sequential-mode (decode) computations produce the same final state →
    // the same next-token logits.
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let tstate = model.init(0, 0.0).unwrap();
    let mut rng = Rng::new(5);
    let tokens: Vec<i32> = (0..4 * 64).map(|_| rng.below(64) as i32)
        .collect();

    // parallel: prefill over the whole window
    let x = Tensor::i32(vec![4, 64], tokens.clone());
    let (par_logits, _) = model.prefill(&tstate.params, &x).unwrap();

    // sequential: token-by-token decode
    let mut st = model.decode_state_zeros(4).unwrap();
    let mut seq_logits = Tensor::zeros_f32(vec![4, 64]);
    for t in 0..64 {
        let xt = Tensor::i32(
            vec![4], (0..4).map(|b| tokens[b * 64 + t]).collect());
        let (l, s) = model.decode_step(&tstate.params, &xt, st).unwrap();
        seq_logits = l;
        st = s;
    }

    let a = par_logits.data.as_f32().unwrap();
    let b = seq_logits.data.as_f32().unwrap();
    let max_err = a.iter().zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-3, "parallel/sequential mismatch: {max_err}");
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn checkpoint_roundtrip_preserves_training() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let mut state = model.init(3, 0.0).unwrap();
    let ds = LmDataset::synthetic(20_000, 0);
    let mut rng = Rng::new(3);
    for i in 0..3 {
        let b = ds.batch(&mut rng, 4, 64);
        model.train_step(&mut state, &b, 1e-3, i).unwrap();
    }
    let dir = std::env::temp_dir().join("minrnn_it_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("it.ckpt");
    model.save_checkpoint(&state, &path).unwrap();
    let restored = model.load_checkpoint(&path).unwrap();
    assert_eq!(restored.step, 3);

    // continuing training from restored state must equal continuing from
    // the original (bitwise deterministic executables)
    let b = ds.batch(&mut rng, 4, 64);
    let mut s1 = state;
    let mut s2 = restored;
    let m1 = model.train_step(&mut s1, &b, 1e-3, 9).unwrap();
    let m2 = model.train_step(&mut s2, &b, 1e-3, 9).unwrap();
    assert_eq!(m1.loss, m2.loss);
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn corrupt_artifact_is_a_clean_error() {
    let (rt, _) = open();
    let dir = std::env::temp_dir().join("minrnn_bad_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.hlo.txt");
    std::fs::write(&bad, "HloModule utter_garbage ha ha").unwrap();
    assert!(rt.load(&bad).is_err());
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn serving_dynamic_batching_end_to_end() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let state = model.init(0, 0.0).unwrap();
    let mut rng = Rng::new(0);
    let requests: Vec<Request> = (0..6).map(|i| Request {
        id: i,
        prompt: (0..3 + rng.usize_below(4))
            .map(|_| rng.below(64) as i32).collect(),
        n_tokens: 5,
        session: None,
    }).collect();
    let backend = PjrtBackend::new(&model, &state.params);
    let stats = serve(&backend, requests, 1.0, 0).unwrap();
    assert_eq!(stats.responses.len(), 6);
    assert!(stats.responses.iter().all(|r| r.tokens.len() == 5));
    assert_eq!(stats.tokens_generated, 30);
}

#[test]
#[cfg_attr(not(feature = "artifacts"),
           ignore = "needs PJRT artifacts: build with --features \
                     artifacts after `make artifacts` (see \
                     rust/README.md)")]
fn trainer_rejects_wrong_shapes() {
    let (rt, manifest) = open();
    let model = Model::open(&rt, manifest, "quickstart").unwrap();
    let mut state = model.init(0, 0.0).unwrap();
    // wrong sequence length → executable must refuse
    let bad = minrnn::tensor::Batch {
        x: Tensor::i32(vec![4, 32], vec![0; 128]),
        targets: Tensor::i32(vec![4, 32], vec![0; 128]),
        mask: Tensor::f32(vec![4, 32], vec![1.0; 128]),
    };
    assert!(model.train_step(&mut state, &bad, 1e-3, 0).is_err());
}

#[test]
fn fn_source_closure_works() {
    // host-only check that the DataSource plumbing composes
    let mut src = FnSource {
        f: |rng: &mut Rng| {
            let ds = LmDataset::synthetic(5_000, 0);
            ds.batch(rng, 2, 16)
        },
    };
    use minrnn::coordinator::trainer::DataSource;
    let mut rng = Rng::new(0);
    let b = src.train_batch(&mut rng);
    assert_eq!(b.x.dims, vec![2, 16]);
}
