//! Cross-module invariants of the data layer (no PJRT needed):
//! generator outputs always match the shapes/vocabs the exported
//! executables expect, across randomized configurations.

use minrnn::data::chomsky;
use minrnn::data::lra::{collate_classification, gimage, listops, retrieval};
use minrnn::data::rl::{OfflineDataset, Regime};
use minrnn::data::selective_copy::SelectiveCopy;
use minrnn::data::{corpus, random_tokens};
use minrnn::tensor::TensorData;
use minrnn::util::rng::Rng;

fn assert_batch_invariants(b: &minrnn::tensor::Batch, vocab_in: i32) {
    let (bs, t) = (b.x.dims[0], b.x.dims[1]);
    assert_eq!(b.mask.dims, vec![bs, t]);
    if let TensorData::I32(x) = &b.x.data {
        assert!(x.iter().all(|&v| v >= 0 && v < vocab_in),
                "token out of vocab {vocab_in}");
    }
    let m = b.mask.data.as_f32().unwrap();
    assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
    assert!(m.iter().any(|&v| v == 1.0), "mask all zeros");
    // targets at masked positions are valid classes
    if let (TensorData::I32(tg), m) = (&b.targets.data, m) {
        for (i, &mask) in m.iter().enumerate() {
            if mask > 0.0 {
                assert!(tg[i] >= 0 && tg[i] < vocab_in,
                        "target {} out of range", tg[i]);
            }
        }
    }
}

#[test]
fn selective_copy_many_configs() {
    let mut rng = Rng::new(0);
    for (ctx, nd) in [(32, 4), (64, 8), (256, 16), (100, 16)] {
        let task = SelectiveCopy::new(ctx, nd);
        for _ in 0..5 {
            let b = task.batch(&mut rng, 3);
            assert_batch_invariants(&b, 16);
            assert_eq!(b.x.dims[1], ctx + nd);
        }
    }
}

#[test]
fn chomsky_tasks_at_many_lengths() {
    let mut rng = Rng::new(1);
    for task in chomsky::all_tasks() {
        for t in [32usize, 64, 128, 288] {
            let max_c = task.max_content_for(t);
            assert!(max_c >= 1, "{}: no content fits in {t}", task.name());
            let b = chomsky::batch(task.as_ref(), &mut rng, 4, t, 1, max_c);
            assert_batch_invariants(&b, 16);
            assert_eq!(b.x.dims, vec![4, t]);
        }
    }
}

#[test]
fn chomsky_deterministic_given_seed() {
    let task = chomsky::BucketSort;
    let b1 = chomsky::batch(&task, &mut Rng::new(7), 4, 64, 1, 20);
    let b2 = chomsky::batch(&task, &mut Rng::new(7), 4, 64, 1, 20);
    assert_eq!(b1.x, b2.x);
    assert_eq!(b1.targets, b2.targets);
}

#[test]
fn lra_generators_fit_exported_shapes() {
    let mut rng = Rng::new(2);
    // listops → T=256, vocab 20
    for _ in 0..10 {
        let examples: Vec<_> = (0..4)
            .map(|_| listops::sample(&mut rng, 246)).collect();
        let b = collate_classification(&examples, 256);
        assert_batch_invariants(&b, 20);
    }
    // retrieval → T=512, vocab 32
    let examples: Vec<_> = (0..4)
        .map(|_| retrieval::sample(&mut rng, 254)).collect();
    let b = collate_classification(&examples, 512);
    assert_batch_invariants(&b, 32);
    // gimage → T=256, vocab 32
    let examples: Vec<_> = (0..4).map(|_| gimage::sample(&mut rng))
        .collect();
    let b = collate_classification(&examples, 256);
    assert_batch_invariants(&b, 32);
}

#[test]
fn corpus_tokens_under_64() {
    let ds = corpus::LmDataset::synthetic(50_000, 0);
    assert!(ds.tokens.iter().all(|&t| (0..64).contains(&t)));
    let mut rng = Rng::new(0);
    let b = ds.batch(&mut rng, 8, 256);
    assert_batch_invariants(&b, 64);
}

#[test]
fn random_tokens_shapes() {
    let mut rng = Rng::new(0);
    for t in [64usize, 1024] {
        let b = random_tokens::batch(&mut rng, 8, t, 16);
        assert_batch_invariants(&b, 16);
    }
}

#[test]
fn rl_batches_match_feature_layout() {
    for env in ["pointmass", "pendulum", "walker1d"] {
        let ds = OfflineDataset::build(env, Regime::Medium, 10, 0);
        let mut rng = Rng::new(0);
        let b = ds.batch(&mut rng, 4, 32);
        assert_eq!(b.x.dims, vec![4, 32, ds.feature_dim()]);
        assert_eq!(b.targets.dims, vec![4, 32, ds.act_dim]);
        let x = b.x.data.as_f32().unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        // actions (targets) bounded by env contract
        let y = b.targets.data.as_f32().unwrap();
        assert!(y.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }
}

#[test]
fn rl_regimes_distinct_data() {
    let m = OfflineDataset::build("pointmass", Regime::Medium, 10, 0);
    let me = OfflineDataset::build("pointmass", Regime::MediumExpert, 10, 0);
    let ret = |d: &OfflineDataset| -> f32 {
        d.episodes.iter().map(|e| e.ret()).sum::<f32>() / 10.0
    };
    assert!(ret(&me) > ret(&m));
}
