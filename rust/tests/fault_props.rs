//! Chaos properties of the fault-injection + recovery stack
//! (`util::faults`, `util::io::commit_durable`, the trainer's checkpoint
//! ring, and the scheduler's self-healing):
//!
//! * **crash-at-every-IO-fault-index** — for every IO fault site and
//!   every occurrence index a training run consults it at, injecting a
//!   one-shot failure there still leaves a checkpoint that
//!   `recover_checkpoint` can find, load, and resume training from.
//!   This includes torn writes published to the final path (`IoShort`),
//!   which only the CRC trailer can catch.
//! * **poisoned-request isolation** — an injected decode panic is
//!   retried via requeue-and-replay, and the surviving greedy output is
//!   bit-identical to a fault-free run at 1, 2, and 7 threads (the
//!   firing schedule is a pure function of `(seed, site, occurrence)`,
//!   and greedy decode is batch-composition invariant).
//! * **quarantine** — a request whose decode *always* fails exhausts its
//!   retry budget and fails alone, without hanging the drain or losing
//!   accounting (`submitted == responses + expired + failed`).
//! * **zero overhead off** — with faults disabled, a serve run leaves
//!   every occurrence counter at zero and reports `Healthy`.
//!
//! The fault plan and its counters are process-global, so every test
//! here serializes on one lock and clears the plan before returning.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use minrnn::backend::{NativeBackend, NativeInit, NativeModel, NativeTrainer};
use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::infer;
use minrnn::coordinator::scheduler::{Backpressure, Scheduler, SchedulerOpts};
use minrnn::coordinator::server::{Health, Request, ServeOpts, ServeStats};
use minrnn::coordinator::trainer::{recover_checkpoint, run_loop, FnSource};
use minrnn::tensor::{Batch, Tensor};
use minrnn::util::faults::{self, FaultPlan, Rule, Site};
use minrnn::util::rng::Rng;
use minrnn::util::threads;

// Serialize every test in this binary: the plan and occurrence counters
// are process-global.  Recover from poisoning — an injected panic that
// crosses a test's unwind must not cascade into the remaining tests.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// tiny training workload (echo task, see train_props.rs)
// ---------------------------------------------------------------------------

const VOCAB: usize = 10;
const LABEL: &str = "fault-echo";

fn echo_batch(rng: &mut Rng, b: usize, t: usize) -> Batch {
    let x: Vec<i32> = (0..b * t).map(|_| rng.below(VOCAB as u64) as i32)
        .collect();
    Batch {
        targets: Tensor::i32(vec![b, t], x.clone()),
        x: Tensor::i32(vec![b, t], x),
        mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
    }
}

fn fresh_trainer(seed: u64) -> NativeTrainer {
    NativeTrainer::new(NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 1,
        d_model: 8,
        vocab_in: Some(VOCAB),
        vocab_out: VOCAB,
        ..Default::default()
    }, seed).unwrap(), LABEL)
}

/// One short checkpointing training run into `dir`: 4 steps, a ring
/// commit every step, ring depth 2 — several `commit_durable` calls per
/// IO site (step saves, LATEST pointer writes, the final save).
fn run_train(dir: &Path) -> anyhow::Result<f32> {
    let mut nt = fresh_trainer(21);
    let cfg = TrainConfig {
        steps: 4,
        lr: 5e-3,
        schedule: Schedule::Constant,
        seed: 3,
        log_every: 1000, // keep test output quiet
        checkpoint: Some(dir.to_path_buf()),
        checkpoint_every: 1,
        keep_checkpoints: 2,
        ..Default::default()
    };
    let mut data = FnSource { f: |rng: &mut Rng| echo_batch(rng, 2, 6) };
    let report = run_loop(&mut nt, &cfg, 0, &mut data)?;
    Ok(report.final_loss)
}

const IO_SITES: [Site; 4] =
    [Site::IoWrite, Site::IoShort, Site::IoFsync, Site::IoRename];

#[test]
fn prop_crash_at_every_io_fault_index_leaves_a_recoverable_checkpoint() {
    let _g = lock();
    let base = std::env::temp_dir().join("minrnn_fault_props_io");
    let _ = std::fs::remove_dir_all(&base);

    // probe: an installed plan with all-default rules fires nothing but
    // counts how often each IO site is consulted by one training run
    faults::install(FaultPlan::default());
    run_train(&base.join("probe")).unwrap();
    let counts: Vec<(Site, u64)> =
        IO_SITES.iter().map(|&s| (s, faults::occurrences(s))).collect();
    faults::clear();

    for &(site, n) in &counts {
        assert!(n >= 4,
                "probe run consulted {} only {n} times — the sweep below \
                 would not mean much", site.name());
        for idx in 0..n {
            let dir = base.join(format!("{}_{idx}", site.name()));
            faults::install(FaultPlan::one_shot(site, idx));
            // checkpoint IO failures are non-fatal: training completes
            let loss = run_train(&dir).unwrap_or_else(|e| panic!(
                "{} fault @{idx} killed the training run: {e:#}",
                site.name()));
            assert!(loss.is_finite());
            faults::clear();

            // recovery must skip whatever the fault tore and land on a
            // checkpoint that still validates and resumes
            let ckpt: PathBuf = recover_checkpoint(&dir, LABEL)
                .unwrap_or_else(|| panic!(
                    "no recoverable checkpoint in {} after {} fault @{idx}",
                    dir.display(), site.name()));
            let mut nt = NativeTrainer::from_checkpoint(&ckpt, LABEL)
                .unwrap_or_else(|e| panic!(
                    "recovered checkpoint {} does not load: {e:#}",
                    ckpt.display()));
            let cfg = TrainConfig {
                steps: 1,
                schedule: Schedule::Constant,
                log_every: 1000,
                ..Default::default()
            };
            let mut data =
                FnSource { f: |rng: &mut Rng| echo_batch(rng, 2, 6) };
            let report = run_loop(&mut nt, &cfg, 0, &mut data).unwrap();
            assert!(report.final_loss.is_finite(),
                    "resumed step after {} fault @{idx} diverged",
                    site.name());
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------------------
// serving under injected decode faults
// ---------------------------------------------------------------------------

fn serving_backend(seed: u64) -> NativeBackend {
    NativeBackend::new(NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 1,
        d_model: 16,
        vocab_in: Some(24),
        vocab_out: 24,
        ..Default::default()
    }, seed).unwrap())
}

fn serve_requests() -> Vec<Request> {
    (0..4).map(|i| Request {
        id: i,
        prompt: vec![1 + i as i32, 2, 3],
        n_tokens: 5,
        session: None,
    }).collect()
}

fn greedy_serve(backend: &NativeBackend) -> ServeStats {
    let (mut sched, handle) = Scheduler::new(backend, SchedulerOpts {
        serve: ServeOpts { temperature: 0.0, seed: 0, max_batch: 4 },
        queue_depth: 8,
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: Some(4),
        ..Default::default()
    }).unwrap();
    for r in serve_requests() {
        handle.submit(r).unwrap();
    }
    handle.close();
    sched.run().unwrap()
}

#[test]
fn prop_injected_decode_panic_replays_bit_identically_across_threads() {
    let _g = lock();
    faults::clear();
    let backend = serving_backend(0xBEEF);
    // fault-free greedy oracle, one request at a time
    let want: Vec<Vec<i32>> = serve_requests().iter().map(|r| {
        infer::generate(&backend, &r.prompt, r.n_tokens, 0.0,
                        &mut Rng::new(0)).unwrap()
    }).collect();

    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // injected panics are expected
    let pool = threads::global();
    let before = pool.active();
    for &n in &[1usize, 2, 7] {
        pool.set_active(n);
        // the second lockstep decode step of the run panics, once;
        // install() resets the counters so the schedule is identical at
        // every thread count
        faults::install(FaultPlan::one_shot(Site::Decode, 1));
        let stats = greedy_serve(&backend);
        faults::clear();
        assert!(stats.retries > 0,
                "{n} threads: the injected panic must be retried");
        assert!(stats.failed.is_empty(),
                "{n} threads: a transient fault must not fail requests");
        assert_eq!(stats.health, Health::Degraded);
        let mut got: Vec<_> = stats.responses.iter().collect();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 4, "{n} threads: all requests served");
        for (r, w) in got.iter().zip(&want) {
            assert_eq!(&r.tokens, w,
                       "{n} threads: request {} diverged after replay",
                       r.id);
        }
    }
    pool.set_active(before);
    std::panic::set_hook(prev);
}

#[test]
fn prop_perpetual_decode_faults_quarantine_without_hanging_the_drain() {
    let _g = lock();
    let backend = serving_backend(0xD00D);
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    // every decode step panics: every request burns its retry budget,
    // including the quarantined single-lane attempts
    faults::install(FaultPlan::default()
        .with(Site::Decode, Rule { rate: 1.0, one_shot: None }));
    let stats = greedy_serve(&backend);
    faults::clear();
    std::panic::set_hook(prev);

    assert!(stats.responses.is_empty());
    let mut failed = stats.failed.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![0, 1, 2, 3],
               "every request fails alone, none is lost");
    assert_eq!(stats.health, Health::Degraded);
    // drain accounting holds even when everything failed
    assert_eq!(stats.submitted,
               stats.responses.len() + stats.expired.len()
                   + stats.failed.len());
}

#[test]
fn injected_latency_spike_slows_but_does_not_degrade() {
    let _g = lock();
    let backend = serving_backend(3);
    let mut plan = FaultPlan::one_shot(Site::Latency, 0);
    plan.latency = std::time::Duration::from_millis(1);
    faults::install(plan);
    let stats = greedy_serve(&backend);
    faults::clear();
    assert_eq!(stats.responses.len(), 4);
    assert_eq!(stats.health, Health::Healthy,
               "latency is not a failure; health must stay Healthy");
}

#[test]
fn faults_disabled_leave_counters_untouched_and_serving_healthy() {
    let _g = lock();
    faults::clear();
    let backend = serving_backend(7);
    let stats = greedy_serve(&backend);
    assert_eq!(stats.responses.len(), 4);
    assert_eq!(stats.health, Health::Healthy);
    assert_eq!(stats.retries, 0);
    for s in Site::ALL {
        assert_eq!(faults::occurrences(s), 0,
                   "disabled faults must not even count occurrences \
                    ({} moved)", s.name());
    }
}
