//! Properties of per-lane session state export/import
//! (`runtime::Backend::{export_state, import_state}` plus the serving
//! path's `coordinator::session_cache`):
//!
//! * **round-trip bit-identity** — export → wire bytes → import → decode
//!   matches uninterrupted decode token-for-token, across thread counts
//!   {1, 2, 7} (the pool is process-global shared state: emulated via
//!   `set_active` like the autograd and dropout tests);
//! * **constant-size state** — the paper's O(1)-in-context payoff: a
//!   snapshot taken after 2 prompt tokens and one taken after 5 prompt
//!   tokens serialize to the same number of bytes;
//! * **lane mobility** — a snapshot exported from one lane of a batched
//!   state resumes bit-identically in a *different* lane of a fresh
//!   state, undisturbed by traffic in the neighbouring lane;
//! * **clean fingerprint rejection** — importing a snapshot exported
//!   from a differently-shaped model (or carrying a tampered
//!   fingerprint) is an error that names the fingerprint, never a shape
//!   panic, and leaves the target state usable;
//! * **warm == cold serving** — replaying identical greedy requests
//!   through `serve_with_cache` hits the session cache (nonzero hit
//!   rate, prefill tokens saved) and returns bit-identical responses;
//! * **inert fallback** — a backend without state export (the PJRT
//!   shape) serves the same tokens with zero cache traffic;
//! * **quantized checkpoints** — an int8-weight model exports/imports
//!   f32 decode state and serves warm==cold like any other backend,
//!   while its fingerprint diverges from the f32 source so stale f32
//!   sessions are refused cleanly.

use std::cell::RefCell;

use minrnn::backend::{NativeBackend, NativeInit, NativeModel, NativeState};
use minrnn::coordinator::infer;
use minrnn::coordinator::server::{serve_opts, serve_with_cache, Request,
                                  ServeOpts, ServeStats};
use minrnn::coordinator::session_cache::SessionCache;
use minrnn::runtime::{Backend, SessionState};
use minrnn::tensor::Tensor;
use minrnn::util::rng::Rng;
use minrnn::util::threads;

const VOCAB: usize = 24;

fn session_backend(seed: u64) -> NativeBackend {
    NativeBackend::new(NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 2,
        d_model: 16,
        expansion: 2,
        vocab_in: Some(VOCAB),
        input_dim: None,
        vocab_out: VOCAB,
        conv: true, // conv ring buffers ride along in the snapshot
        mlp: true,
        mlp_mult: 2,
        forget_bias: 0.5,
        ..NativeInit::default()
    }, seed).unwrap())
}

fn session_requests(rng: &mut Rng, n: usize) -> Vec<Request> {
    (0..n).map(|i| Request {
        id: i as u64,
        prompt: (0..4 + rng.usize_below(4))
            .map(|_| rng.below(VOCAB as u64) as i32).collect(),
        n_tokens: 6,
        session: Some(i as u64),
    }).collect()
}

/// Greedy batch-1 continuation from `(state, logits)`.
fn greedy_continue(backend: &NativeBackend, mut state: NativeState,
                   mut logits: Tensor, n: usize) -> Vec<i32> {
    let mut rng = Rng::new(0);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let row = logits.data.as_f32().unwrap();
        let next = infer::sample_logits(row, 0.0, &mut rng) as i32;
        out.push(next);
        let x = Tensor::i32(vec![1], vec![next]);
        let (l, s) = backend.decode_step(&x, state).unwrap();
        logits = l;
        state = s;
    }
    out
}

fn tokens_by_id(stats: &ServeStats) -> Vec<(u64, Vec<i32>)> {
    let mut v: Vec<(u64, Vec<i32>)> = stats.responses.iter()
        .map(|r| (r.id, r.tokens.clone())).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

// ---------------------------------------------------------------------------
// round-trip bit-identity across thread counts, constant-size state
// ---------------------------------------------------------------------------

#[test]
fn export_import_roundtrip_is_bit_identical_across_thread_counts() {
    let backend = session_backend(11);
    let prompt = [3i32, 7, 1, 19, 4, 2];
    let pool = threads::global();
    let before = pool.active();
    let mut by_threads: Vec<Vec<i32>> = Vec::new();
    for n in [1usize, 2, 7] {
        pool.set_active(n);
        // chain A: uninterrupted decode, with a snapshot taken just
        // before the final prompt token (the scheduler restores at most
        // prompt.len() - 1 positions so the admitted lane still produces
        // last-token logits to sample from)
        let mut state = backend.decode_state(1).unwrap();
        let mut early = None;
        let mut snap = None;
        let mut logits = Tensor::zeros_f32(vec![1, 1]);
        for (i, &tok) in prompt.iter().enumerate() {
            if i == 2 {
                early = Some(backend.export_state(&state, 0).unwrap());
            }
            if i + 1 == prompt.len() {
                snap = Some(backend.export_state(&state, 0).unwrap());
            }
            let x = Tensor::i32(vec![1], vec![tok]);
            let (l, s) = backend.decode_step(&x, state).unwrap();
            logits = l;
            state = s;
        }
        let snap = snap.unwrap();
        // the constant-size-state payoff: snapshots after 2 and after 5
        // context tokens serialize to the same number of bytes
        assert_eq!(early.unwrap().bytes.len(), snap.bytes.len(),
                   "decode-state snapshot must be O(1) in context");
        let a = greedy_continue(&backend, state, logits, 12);

        // chain B: snapshot -> wire format -> fresh state, then replay
        // only the final prompt token
        let wire = snap.to_bytes();
        assert!(SessionState::from_bytes(&wire[..wire.len() - 3]).is_err(),
                "truncated wire bytes must be rejected");
        let wired = SessionState::from_bytes(&wire).unwrap();
        assert_eq!(wired.fingerprint, snap.fingerprint);
        assert_eq!(wired.bytes, snap.bytes);
        let mut fresh = backend.decode_state(1).unwrap();
        backend.import_state(&mut fresh, 0, &wired).unwrap();
        let x = Tensor::i32(vec![1], vec![prompt[prompt.len() - 1]]);
        let (logits, fresh) = backend.decode_step(&x, fresh).unwrap();
        let b = greedy_continue(&backend, fresh, logits, 12);
        assert_eq!(a, b, "resumed decode diverged at {n} threads");
        by_threads.push(a);
    }
    pool.set_active(before);
    for other in &by_threads[1..] {
        assert_eq!(&by_threads[0], other,
                   "decode differs across thread counts");
    }
}

// ---------------------------------------------------------------------------
// lane mobility: export lane 0, resume in lane 1 of another state
// ---------------------------------------------------------------------------

#[test]
fn snapshots_resume_in_a_different_lane_of_a_batched_state() {
    let backend = session_backend(29);
    let prompt = [5i32, 12, 8, 3, 17];

    // batch-1 reference continuation
    let mut state = backend.decode_state(1).unwrap();
    let mut logits = Tensor::zeros_f32(vec![1, 1]);
    for &tok in &prompt {
        let x = Tensor::i32(vec![1], vec![tok]);
        let (l, s) = backend.decode_step(&x, state).unwrap();
        logits = l;
        state = s;
    }
    let want = greedy_continue(&backend, state, logits, 10);

    // lane 0 of a batch-2 state follows the prompt while lane 1 sees
    // unrelated traffic; export lane 0 just before the final token
    let mut batched = backend.decode_state(2).unwrap();
    let mut snap = None;
    for (i, &tok) in prompt.iter().enumerate() {
        if i + 1 == prompt.len() {
            snap = Some(backend.export_state(&batched, 0).unwrap());
        }
        let noise = ((i * 7) % VOCAB) as i32;
        let x = Tensor::i32(vec![2], vec![tok, noise]);
        let (_, s) = backend.decode_step(&x, batched).unwrap();
        batched = s;
    }

    // resume in lane 1 of a fresh batch-2 state; lane 0 is now the
    // noisy neighbour and must not disturb the restored lane
    let mut resumed = backend.decode_state(2).unwrap();
    backend.import_state(&mut resumed, 1, &snap.unwrap()).unwrap();
    let x = Tensor::i32(vec![2], vec![9, prompt[prompt.len() - 1]]);
    let (mut logits, mut resumed) = backend.decode_step(&x, resumed)
        .unwrap();
    let mut rng = Rng::new(0);
    let mut got = Vec::with_capacity(10);
    for step in 0..10 {
        let buf = logits.data.as_f32().unwrap();
        let next = infer::sample_logits(&buf[VOCAB..2 * VOCAB], 0.0,
                                        &mut rng) as i32;
        got.push(next);
        let noise = ((step * 5) % VOCAB) as i32;
        let x = Tensor::i32(vec![2], vec![noise, next]);
        let (l, s) = backend.decode_step(&x, resumed).unwrap();
        logits = l;
        resumed = s;
    }
    assert_eq!(got, want,
               "lane-1 resume diverged from the batch-1 reference");
}

// ---------------------------------------------------------------------------
// fingerprint mismatch: clean error, not a shape panic
// ---------------------------------------------------------------------------

#[test]
fn mismatched_fingerprint_is_a_clean_error_not_a_shape_panic() {
    let backend = session_backend(3);
    // a differently-shaped model: more layers, wider, no conv/mlp — its
    // per-lane state would slice the target's buffers out of bounds if
    // import ever got as far as copying
    let other = NativeBackend::new(NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 3,
        d_model: 32,
        expansion: 2,
        vocab_in: Some(VOCAB),
        input_dim: None,
        vocab_out: VOCAB,
        conv: false,
        mlp: false,
        mlp_mult: 2,
        forget_bias: 0.5,
        ..NativeInit::default()
    }, 3).unwrap());
    assert_ne!(backend.state_fingerprint(), other.state_fingerprint(),
               "differently shaped models must fingerprint differently");

    let x = Tensor::i32(vec![1], vec![4]);
    let (_, st) = other.decode_step(&x, other.decode_state(1).unwrap())
        .unwrap();
    let foreign = other.export_state(&st, 0).unwrap();

    let mut state = backend.decode_state(1).unwrap();
    let err = backend.import_state(&mut state, 0, &foreign).unwrap_err();
    assert!(err.to_string().contains("fingerprint"),
            "unexpected error: {err}");

    // a tampered fingerprint on otherwise-valid bytes is refused too
    let own = backend.export_state(&state, 0).unwrap();
    let tampered = SessionState {
        fingerprint: own.fingerprint ^ 1,
        bytes: own.bytes.clone(),
    };
    assert!(backend.import_state(&mut state, 0, &tampered).is_err());

    // both refusals happened before any write: the state is still usable
    let (logits, _) = backend.decode_step(&x, state).unwrap();
    assert_eq!(logits.dims, vec![1, VOCAB]);
}

// ---------------------------------------------------------------------------
// warm serving through the cache is bit-identical to the cold run
// ---------------------------------------------------------------------------

#[test]
fn warm_session_serving_is_bit_identical_to_cold() {
    let backend = session_backend(0x5E55);
    let requests = session_requests(&mut Rng::new(9), 6);
    let opts = ServeOpts { temperature: 0.0, seed: 0, max_batch: 3 };
    let cache = RefCell::new(SessionCache::new(4 << 20));

    let cold = serve_with_cache(&backend, requests.clone(), &opts,
                                &cache).unwrap();
    assert_eq!(cold.session_hits, 0);
    assert!(cold.session_misses > 0);
    assert!(!cache.borrow().is_empty(),
            "the cold run must populate the cache");

    let warm = serve_with_cache(&backend, requests.clone(), &opts,
                                &cache).unwrap();
    assert_eq!(warm.session_hits, requests.len(),
               "every replayed request must hit its cached prefix");
    assert!(warm.prefill_tokens_saved > 0,
            "cache hits must skip prompt decode steps");
    assert_eq!(tokens_by_id(&cold), tokens_by_id(&warm),
               "cache-hit decode must be bit-identical to fresh prefill");
}

// ---------------------------------------------------------------------------
// a backend without state export serves correctly with an inert cache
// ---------------------------------------------------------------------------

/// A native backend masquerading as one whose state cannot leave the
/// device (the PJRT shape): decode and lane reset work, but the default
/// `state_fingerprint` (None) and `export_state`/`import_state`
/// (unsupported) stand, so the session cache must stay inert.
struct NoExportBackend(NativeBackend);

impl Backend for NoExportBackend {
    type State = NativeState;

    fn name(&self) -> &str {
        "native-noexport"
    }

    fn step_batches(&self) -> Vec<usize> {
        self.0.step_batches()
    }

    fn decode_state(&self, batch: usize) -> anyhow::Result<NativeState> {
        self.0.decode_state(batch)
    }

    fn decode_step(&self, x_t: &Tensor, state: NativeState)
                   -> anyhow::Result<(Tensor, NativeState)> {
        self.0.decode_step(x_t, state)
    }

    fn prefill(&self, x: &Tensor) -> anyhow::Result<(Tensor, NativeState)> {
        self.0.prefill(x)
    }

    fn reset_lane(&self, state: &mut NativeState, lane: usize) -> bool {
        self.0.reset_lane(state, lane)
    }

    fn lane_reset_supported(&self) -> bool {
        self.0.lane_reset_supported()
    }
}

// ---------------------------------------------------------------------------
// quantized checkpoints serve sessions like any other model
// ---------------------------------------------------------------------------

/// The int8 payload quantizes *weights*; decode state stays f32, so
/// session snapshots export/import and the warm cache works unchanged —
/// while the fingerprint (deliberately) diverges from the f32 source,
/// so stale f32 sessions can never resume against the quantized model.
#[test]
fn quantized_backend_sessions_roundtrip_and_serve_warm() {
    use minrnn::backend::native::quant;
    let f32_backend = session_backend(0x17E8);
    let mut qm = f32_backend.model.clone();
    quant::quantize_model(&mut qm).unwrap();
    let backend = NativeBackend::new(qm);
    assert_ne!(backend.state_fingerprint(),
               f32_backend.state_fingerprint(),
               "quantization must re-key the session namespace");

    // export → wire → import round-trip, bit-identical continuation
    let prompt = [2i32, 9, 14, 6, 1];
    let mut state = backend.decode_state(1).unwrap();
    let mut snap = None;
    let mut logits = Tensor::zeros_f32(vec![1, 1]);
    for (i, &tok) in prompt.iter().enumerate() {
        if i + 1 == prompt.len() {
            snap = Some(backend.export_state(&state, 0).unwrap());
        }
        let x = Tensor::i32(vec![1], vec![tok]);
        let (l, s) = backend.decode_step(&x, state).unwrap();
        logits = l;
        state = s;
    }
    let want = greedy_continue(&backend, state, logits, 10);
    let wired = SessionState::from_bytes(&snap.unwrap().to_bytes())
        .unwrap();
    let mut fresh = backend.decode_state(1).unwrap();
    backend.import_state(&mut fresh, 0, &wired).unwrap();
    let x = Tensor::i32(vec![1], vec![prompt[prompt.len() - 1]]);
    let (logits, fresh) = backend.decode_step(&x, fresh).unwrap();
    assert_eq!(want, greedy_continue(&backend, fresh, logits, 10),
               "quantized-backend resume diverged");

    // warm == cold serving through the session cache
    let requests = session_requests(&mut Rng::new(41), 5);
    let opts = ServeOpts { temperature: 0.0, seed: 0, max_batch: 2 };
    let cache = RefCell::new(SessionCache::new(4 << 20));
    let cold = serve_with_cache(&backend, requests.clone(), &opts,
                                &cache).unwrap();
    assert!(cold.session_misses > 0);
    let warm = serve_with_cache(&backend, requests.clone(), &opts,
                                &cache).unwrap();
    assert_eq!(warm.session_hits, requests.len(),
               "every replayed request must hit the quantized cache");
    assert!(warm.prefill_tokens_saved > 0);
    assert_eq!(tokens_by_id(&cold), tokens_by_id(&warm),
               "warm quantized serving must match cold bit for bit");

    // an f32-model snapshot is refused by fingerprint, never imported
    let f32_state = {
        let x = Tensor::i32(vec![1], vec![4]);
        let st = f32_backend.decode_state(1).unwrap();
        let (_, st) = f32_backend.decode_step(&x, st).unwrap();
        st
    };
    let stale = f32_backend.export_state(&f32_state, 0).unwrap();
    let mut target = backend.decode_state(1).unwrap();
    let err = backend.import_state(&mut target, 0, &stale).unwrap_err();
    assert!(err.to_string().contains("fingerprint"),
            "unexpected error: {err}");
}

#[test]
fn backend_without_state_export_serves_with_an_inert_cache() {
    let native = session_backend(0xFA11);
    let requests = session_requests(&mut Rng::new(31), 5);
    let opts = ServeOpts { temperature: 0.0, seed: 0, max_batch: 2 };
    let want = serve_opts(&native, requests.clone(), &opts).unwrap();

    let backend = NoExportBackend(native);
    assert!(backend.state_fingerprint().is_none());
    assert!(backend
        .export_state(&backend.decode_state(1).unwrap(), 0)
        .is_err());

    let cache = RefCell::new(SessionCache::new(1 << 20));
    let stats = serve_with_cache(&backend, requests, &opts, &cache)
        .unwrap();
    assert_eq!(stats.session_hits, 0);
    assert_eq!(stats.session_misses, 0);
    assert_eq!(stats.prefill_tokens_saved, 0);
    assert!(cache.borrow().is_empty(), "no state export, no entries");
    assert_eq!(tokens_by_id(&want), tokens_by_id(&stats),
               "an inert cache must not change served tokens");
}
