//! Golden-vector tests: the native pure-Rust backend versus the JAX
//! reference oracles (`python/compile/kernels/ref.py` and
//! `python/compile/models/backbone.py`).
//!
//! The vectors under `tests/golden/` are committed JSON produced by
//! `python -m compile.export_golden`; these tests need **no artifacts, no
//! Python, no PJRT** and never skip.  Tolerance is hybrid
//! absolute + relative (`|a - b| < 1e-6 + 1e-5 * |b|`, like the scan
//! chunk-seam test's relative form): *tighter* than the old fixed 1e-5
//! absolute for |ref| < 1 (which was masking relative regressions behind
//! small magnitudes) while scaling properly for large-magnitude backbone
//! outputs.  Measured headroom: the native kernels match these vectors to
//! ~4e-8 absolute, ~40x inside the gate.

use std::path::Path;

use minrnn::backend::native::linalg::{g, log_g, sigmoid, softplus};
use minrnn::backend::native::scan;
use minrnn::backend::{NativeBackend, NativeModel};
use minrnn::coordinator::{infer, server};
use minrnn::runtime::Backend;
use minrnn::tensor::Tensor;
use minrnn::util::io::{self, NamedTensor};
use minrnn::util::json::{self, Json};
use minrnn::util::rng::Rng;

/// Absolute floor of the tolerance (f32 kernel noise at tiny magnitudes).
const ATOL: f32 = 1e-6;
/// Relative component, dominant for |ref| > 0.1.
const RTOL: f32 = 1e-5;

fn load_json(name: &str) -> Json {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden").join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e} — regenerate with \
                                    `python -m compile.export_golden`",
                                   path.display()));
    json::parse(&text).unwrap_or_else(|e| panic!("parse {name}: {e}"))
}

fn shape_of(j: &Json) -> Vec<usize> {
    j.req("shape").unwrap().as_arr().unwrap().iter()
        .map(|d| d.as_usize().unwrap()).collect()
}

fn f32s(j: &Json) -> (Vec<usize>, Vec<f32>) {
    let data = j.req("data").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as f32).collect();
    (shape_of(j), data)
}

fn i32s(j: &Json) -> (Vec<usize>, Vec<i32>) {
    let data = j.req("data").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_i64().unwrap() as i32).collect();
    (shape_of(j), data)
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let tol = ATOL + RTOL * b.abs();
        assert!((a - b).abs() < tol,
                "{what}[{i}]: native {a} vs reference {b} \
                 (|diff| = {}, tol = {tol})", (a - b).abs());
    }
}

// ---------------------------------------------------------------------------
// mixer cells (Algorithms 5/7) — both the step formula and the log-space
// scan path must reproduce the reference state sequence
// ---------------------------------------------------------------------------

#[test]
fn golden_mingru_cell() {
    let doc = load_json("mingru_cells.json");
    let cases = doc.req("cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let (dims, k) = f32s(case.req("k").unwrap());
        let (_, pre) = f32s(case.req("pre").unwrap());
        let (_, h0) = f32s(case.req("h0").unwrap());
        let (_, want) = f32s(case.req("h").unwrap());
        let (b, t, d) = (dims[0], dims[1], dims[2]);

        // sequential decode formula (Algorithm 5)
        let mut h = h0.clone();
        let mut got_seq = vec![0.0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                for di in 0..d {
                    let off = (bi * t + ti) * d + di;
                    let z = sigmoid(k[off]);
                    let hi = bi * d + di;
                    h[hi] = (1.0 - z) * h[hi] + z * g(pre[off]);
                    got_seq[off] = h[hi];
                }
            }
        }
        assert_close(&got_seq, &want, &format!("mingru case {ci} (step)"));

        // log-space scan path (Algorithm 6)
        let n = b * t * d;
        let mut log_a = vec![0.0f32; n];
        let mut log_b = vec![0.0f32; n];
        for i in 0..n {
            log_a[i] = -softplus(k[i]);
            log_b[i] = -softplus(-k[i]) + log_g(pre[i]);
        }
        let log_h0: Vec<f32> = h0.iter().map(|&v| v.ln()).collect();
        let got_scan = scan::scan_log(&log_a, &log_b, &log_h0, b, t, d);
        assert_close(&got_scan, &want, &format!("mingru case {ci} (scan)"));
    }
}

#[test]
fn golden_minlstm_cell() {
    let doc = load_json("minlstm_cells.json");
    let cases = doc.req("cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let (dims, p) = f32s(case.req("p").unwrap());
        let (_, k) = f32s(case.req("k").unwrap());
        let (_, pre) = f32s(case.req("pre").unwrap());
        let (_, h0) = f32s(case.req("h0").unwrap());
        let (_, want) = f32s(case.req("h").unwrap());
        let (b, t, d) = (dims[0], dims[1], dims[2]);

        // sequential decode formula (Algorithm 7)
        let mut h = h0.clone();
        let mut got_seq = vec![0.0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                for di in 0..d {
                    let off = (bi * t + ti) * d + di;
                    let f = sigmoid(p[off]);
                    let i = sigmoid(k[off]);
                    let denom = f + i;
                    let hi = bi * d + di;
                    h[hi] = (f / denom) * h[hi]
                        + (i / denom) * g(pre[off]);
                    got_seq[off] = h[hi];
                }
            }
        }
        assert_close(&got_seq, &want, &format!("minlstm case {ci} (step)"));

        // log-space scan path (Algorithm 8)
        let n = b * t * d;
        let mut log_a = vec![0.0f32; n];
        let mut log_b = vec![0.0f32; n];
        for i in 0..n {
            let diff = softplus(-p[i]) - softplus(-k[i]);
            log_a[i] = -softplus(diff);
            log_b[i] = -softplus(-diff) + log_g(pre[i]);
        }
        let log_h0: Vec<f32> = h0.iter().map(|&v| v.ln()).collect();
        let got_scan = scan::scan_log(&log_a, &log_b, &log_h0, b, t, d);
        assert_close(&got_scan, &want, &format!("minlstm case {ci} (scan)"));
    }
}

#[test]
fn golden_scan_primitives() {
    let doc = load_json("scan_cases.json");
    for (ci, case) in doc.req("log").unwrap().as_arr().unwrap().iter()
        .enumerate() {
        let (dims, la) = f32s(case.req("log_a").unwrap());
        let (_, lb) = f32s(case.req("log_b").unwrap());
        let (_, lh0) = f32s(case.req("log_h0").unwrap());
        let (_, want) = f32s(case.req("h").unwrap());
        let (b, t, d) = (dims[0], dims[1], dims[2]);
        let chunked = scan::scan_log(&la, &lb, &lh0, b, t, d);
        let seq = scan::scan_log_seq(&la, &lb, &lh0, b, t, d);
        assert_close(&chunked, &want, &format!("scan_log case {ci}"));
        assert_close(&seq, &want, &format!("scan_log_seq case {ci}"));
    }
    for (ci, case) in doc.req("linear").unwrap().as_arr().unwrap().iter()
        .enumerate() {
        let (dims, a) = f32s(case.req("a").unwrap());
        let (_, bb) = f32s(case.req("b").unwrap());
        let (_, h0) = f32s(case.req("h0").unwrap());
        let (_, want) = f32s(case.req("h").unwrap());
        let (b, t, d) = (dims[0], dims[1], dims[2]);
        let got = scan::scan_linear(&a, &bb, &h0, b, t, d);
        assert_close(&got, &want, &format!("scan_linear case {ci}"));
    }
}

// ---------------------------------------------------------------------------
// full backbone
// ---------------------------------------------------------------------------

fn model_from_golden(doc: &Json) -> NativeModel {
    let named: Vec<NamedTensor> = doc.req("params").unwrap().as_arr()
        .unwrap().iter().map(|p| {
            let name = p.req("name").unwrap().as_str().unwrap().to_string();
            let (dims, data) = f32s(p);
            NamedTensor { name, dims, data: io::TensorData::F32(data) }
        }).collect();
    NativeModel::from_named(&named).expect("build model from golden params")
}

/// Shared token-input backbone check: parallel forward (prefill path)
/// against `logits_parallel`, then the sequential decode chain against
/// `logits_step`.
fn assert_token_backbone(doc: &Json, model: &NativeModel, what: &str) {
    let (xdims, tokens) = i32s(doc.req("x").unwrap());
    let (b, t) = (xdims[0], xdims[1]);
    let (_, want_par) = f32s(doc.req("logits_parallel").unwrap());
    let (_, want_step) = f32s(doc.req("logits_step").unwrap());

    // parallel forward (prefill path)
    let x = Tensor::i32(vec![b, t], tokens.clone());
    let (all, _) = model.forward(&x).unwrap();
    assert_eq!(all.dims, vec![b, t, model.vocab_out]);
    assert_close(all.data.as_f32().unwrap(), &want_par,
                 &format!("{what} forward"));

    // sequential decode chain
    let v = model.vocab_out;
    let mut st = model.init_state(b);
    let mut got = vec![0.0f32; b * t * v];
    for ti in 0..t {
        let xt = Tensor::i32(
            vec![b], (0..b).map(|bi| tokens[bi * t + ti]).collect());
        let (logits, st2) = model.step(&xt, st).unwrap();
        st = st2;
        let lv = logits.data.as_f32().unwrap();
        for bi in 0..b {
            got[(bi * t + ti) * v..(bi * t + ti + 1) * v]
                .copy_from_slice(&lv[bi * v..(bi + 1) * v]);
        }
    }
    assert_close(&got, &want_step, &format!("{what} decode"));
}

#[test]
fn golden_backbone_mingru_forward_and_decode() {
    let doc = load_json("backbone_mingru.json");
    let model = model_from_golden(&doc);
    assert_eq!(model.kind(), "mingru");
    assert_eq!(model.n_layers(), 2);
    assert_token_backbone(&doc, &model, "backbone_mingru");
}

#[test]
fn golden_backbone_s6lite_forward_and_decode() {
    // the selective scan (input-dependent decay) against the JAX oracle:
    // Δ/B from the token stream, real-space scan, gated SiLU output
    let doc = load_json("backbone_s6lite.json");
    let model = model_from_golden(&doc);
    assert_eq!(model.kind(), "s6lite");
    assert_token_backbone(&doc, &model, "backbone_s6lite");
}

#[test]
fn golden_backbone_transformer_forward_and_decode() {
    // causal attention + learned positions against the JAX oracle; the
    // decode chain exercises the per-lane KV ring (T <= max_len here, so
    // the sliding window never engages and JAX parity holds)
    let doc = load_json("backbone_transformer.json");
    let model = model_from_golden(&doc);
    assert_eq!(model.kind(), "transformer");
    assert_token_backbone(&doc, &model, "backbone_transformer");
}

#[test]
fn golden_backbone_minlstm_continuous_input() {
    let doc = load_json("backbone_minlstm.json");
    let model = model_from_golden(&doc);
    assert_eq!(model.kind(), "minlstm");

    let (xdims, feats) = f32s(doc.req("x").unwrap());
    let (b, t, f) = (xdims[0], xdims[1], xdims[2]);
    let (_, want_par) = f32s(doc.req("logits_parallel").unwrap());
    let (_, want_step) = f32s(doc.req("logits_step").unwrap());

    let x = Tensor::f32(vec![b, t, f], feats.clone());
    let (all, _) = model.forward(&x).unwrap();
    assert_close(all.data.as_f32().unwrap(), &want_par,
                 "backbone_minlstm forward");

    let v = model.vocab_out;
    let mut st = model.init_state(b);
    let mut got = vec![0.0f32; b * t * v];
    for ti in 0..t {
        let mut row = vec![0.0f32; b * f];
        for bi in 0..b {
            row[bi * f..(bi + 1) * f].copy_from_slice(
                &feats[(bi * t + ti) * f..(bi * t + ti + 1) * f]);
        }
        let xt = Tensor::f32(vec![b, f], row);
        let (logits, st2) = model.step(&xt, st).unwrap();
        st = st2;
        let lv = logits.data.as_f32().unwrap();
        for bi in 0..b {
            got[(bi * t + ti) * v..(bi * t + ti + 1) * v]
                .copy_from_slice(&lv[bi * v..(bi + 1) * v]);
        }
    }
    assert_close(&got, &want_step, "backbone_minlstm decode");
}

// ---------------------------------------------------------------------------
// end-to-end, artifact-free: checkpoint → generate → serve
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_generate_serve_without_artifacts() {
    // golden params → MRNN checkpoint on disk → native backend → tokens
    let doc = load_json("backbone_mingru.json");
    let model = model_from_golden(&doc);
    let vocab = model.vocab_out;

    let dir = std::env::temp_dir().join("minrnn_native_golden");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("golden.ckpt");
    io::save(&ckpt, &model.to_named()).unwrap();

    let backend = NativeBackend::from_checkpoint(&ckpt).unwrap();
    // the reloaded model is bit-identical
    let x = Tensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
    let (a, _) = model.forward(&x).unwrap();
    let (b, _) = backend.model.forward(&x).unwrap();
    assert_eq!(a, b, "checkpoint round-trip must be bit-exact");

    // generate: prompt ingestion + sampling, O(1)/token decode
    let mut rng = Rng::new(0);
    let out = infer::generate(&backend, &[1, 2, 3], 16, 1.0, &mut rng)
        .unwrap();
    assert_eq!(out.len(), 16);
    assert!(out.iter().all(|&tok| (0..vocab as i32).contains(&tok)));

    // greedy decode is deterministic
    let mut r1 = Rng::new(7);
    let mut r2 = Rng::new(8);
    let g1 = infer::generate(&backend, &[5, 6], 8, 0.0, &mut r1).unwrap();
    let g2 = infer::generate(&backend, &[5, 6], 8, 0.0, &mut r2).unwrap();
    assert_eq!(g1, g2);

    // prefill state continues into decode identically to step-by-step
    let ctx = Tensor::i32(vec![1, 4], vec![2, 4, 6, 8]);
    let (pl, pstate) = backend.prefill(&ctx).unwrap();
    let mut sstate = backend.decode_state(1).unwrap();
    let mut sl = Tensor::zeros_f32(vec![1, 1]);
    for &tok in &[2, 4, 6, 8] {
        let (l, s) = backend
            .decode_step(&Tensor::i32(vec![1], vec![tok]), sstate)
            .unwrap();
        sl = l;
        sstate = s;
    }
    let (pv, sv) = (pl.data.as_f32().unwrap(), sl.data.as_f32().unwrap());
    for i in 0..pv.len() {
        assert!((pv[i] - sv[i]).abs() < 1e-4,
                "prefill/decode logits diverge at {i}");
    }

    // dynamic-batched serving end-to-end
    let requests: Vec<server::Request> = (0..5).map(|i| server::Request {
        id: i,
        prompt: vec![1 + i as i32, 2, 3],
        n_tokens: 6,
        session: None,
    }).collect();
    let stats = server::serve(&backend, requests, 0.9, 1).unwrap();
    assert_eq!(stats.responses.len(), 5);
    assert!(stats.responses.iter().all(|r| r.tokens.len() == 6));
    assert_eq!(stats.tokens_generated, 30);
}
