//! Properties of the async admission-controlled scheduler
//! (`coordinator::scheduler`):
//!
//! * **equivalence** — greedy (temperature 0) scheduler output is
//!   bit-identical to per-request sequential decode, under randomized
//!   arrival order, randomized submit/step interleaving, and queue depths
//!   {1, 2, 7};
//! * **mid-decode admission** — a request submitted long after decoding
//!   started completes inside the *same* batch (no restart), which is the
//!   capability the PR-2 `Vec<Request>` API lacked;
//! * **graceful drain** — closing the queue loses no submitted request
//!   and duplicates none, including when submissions race in from another
//!   thread;
//! * **run-to-completion fallback** — a backend without lane reset (the
//!   PJRT shape) still serves everything, across multiple batches;
//! * **mid-decode deadline expiry** — a request whose deadline elapses
//!   while a long decode step is in flight is expired at the *next*
//!   admission pass: never served late, never double-counted in drain
//!   accounting.
//!
//! Determinism comes from the scheduler's pump design: `step()` performs
//! one admission pass plus one lockstep decode step and never blocks, so
//! a test controls the exact interleaving of arrivals and decode work.

use std::collections::VecDeque;

use minrnn::backend::{NativeBackend, NativeInit, NativeModel, NativeState};
use minrnn::coordinator::infer;
use minrnn::coordinator::scheduler::{Backpressure, Scheduler, SchedulerOpts,
                                     SubmitError};
use minrnn::coordinator::server::{Request, ServeOpts};
use minrnn::runtime::Backend;
use minrnn::tensor::Tensor;
use minrnn::util::rng::Rng;

fn serving_backend(seed: u64) -> NativeBackend {
    NativeBackend::new(NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 2,
        d_model: 16,
        expansion: 2,
        vocab_in: Some(24),
        input_dim: None,
        vocab_out: 24,
        conv: true, // exercises conv ring-buffer lane reset on admission
        mlp: true,
        mlp_mult: 2,
        forget_bias: 0.5,
        ..NativeInit::default()
    }, seed).unwrap())
}

fn random_requests(rng: &mut Rng, n: usize) -> Vec<Request> {
    (0..n).map(|i| Request {
        id: i as u64,
        prompt: (0..1 + rng.usize_below(5))
            .map(|_| rng.below(24) as i32).collect(),
        n_tokens: 3 + rng.usize_below(5),
        session: None,
    }).collect()
}

/// Greedy sequential decode, the oracle every scheduler run must match.
fn sequential_oracle(backend: &NativeBackend, requests: &[Request])
                     -> Vec<Vec<i32>> {
    requests.iter().map(|req| {
        infer::generate(backend, &req.prompt, req.n_tokens, 0.0,
                        &mut Rng::new(0)).unwrap()
    }).collect()
}

fn assert_ids_complete(responses: &[minrnn::coordinator::server::Response],
                       n: usize, label: &str) {
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    let want: Vec<u64> = (0..n as u64).collect();
    assert_eq!(ids, want, "{label}: lost or duplicated requests");
}

// ---------------------------------------------------------------------------
// equivalence under randomized arrivals and queue depths
// ---------------------------------------------------------------------------

#[test]
fn prop_async_greedy_matches_sequential_across_queue_depths() {
    let backend = serving_backend(0xFACE);
    let mut rng = Rng::new(2024);
    let requests = random_requests(&mut rng, 10);
    let want = sequential_oracle(&backend, &requests);

    for &depth in &[1usize, 2, 7] {
        let mut arrival = Rng::new(1000 + depth as u64);
        let (mut sched, handle) = Scheduler::new(&backend, SchedulerOpts {
            serve: ServeOpts { temperature: 0.0, seed: 9, max_batch: 3 },
            queue_depth: depth,
            backpressure: Backpressure::Reject,
            default_deadline: None,
            lanes: Some(3),
            ..Default::default()
        }).unwrap();

        // randomized arrival order...
        let mut order: Vec<usize> = (0..requests.len()).collect();
        arrival.shuffle(&mut order);
        // ...interleaved with a randomized number of decode steps
        let mut backlog: VecDeque<Request> =
            order.iter().map(|&i| requests[i].clone()).collect();
        while let Some(req) = backlog.pop_front() {
            for _ in 0..arrival.usize_below(4) {
                sched.step().unwrap();
            }
            // reject backpressure: retry after making decode progress,
            // which is what frees queue slots
            let mut r = req;
            loop {
                match handle.submit(r) {
                    Ok(()) => break,
                    Err(SubmitError::QueueFull(back)) => {
                        r = back;
                        sched.step().unwrap();
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }
        handle.close();
        let stats = sched.run().unwrap();

        assert_eq!(stats.responses.len(), requests.len(), "depth {depth}");
        assert_ids_complete(&stats.responses, requests.len(),
                            &format!("depth {depth}"));
        for resp in &stats.responses {
            assert_eq!(resp.tokens, want[resp.id as usize],
                       "depth {depth}: request {} diverged from \
                        sequential decode", resp.id);
        }
        assert_eq!(stats.admitted, requests.len());
        assert!(stats.expired.is_empty());
        assert_eq!(stats.tokens_generated,
                   requests.iter().map(|r| r.n_tokens).sum::<usize>());
    }
}

// ---------------------------------------------------------------------------
// mid-decode admission (the acceptance property)
// ---------------------------------------------------------------------------

#[test]
fn late_submission_completes_without_restarting_the_batch() {
    let backend = serving_backend(0xBEEF);
    let a = Request { id: 0, prompt: vec![1, 2, 3], n_tokens: 12,
                      session: None };
    let b = Request { id: 1, prompt: vec![4, 5], n_tokens: 4,
                      session: None };
    let want = sequential_oracle(&backend, &[a.clone(), b.clone()]);

    let (mut sched, handle) = Scheduler::new(&backend, SchedulerOpts {
        serve: ServeOpts { temperature: 0.0, seed: 0, max_batch: 2 },
        queue_depth: 4,
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: Some(2),
        ..Default::default()
    }).unwrap();

    handle.submit(a).unwrap();
    // decode well past the prompt: the batch is unambiguously mid-flight
    for _ in 0..6 {
        assert!(sched.step().unwrap());
    }
    assert_eq!(sched.batches_started(), 1);
    assert_eq!(sched.active_lanes(), 1);
    assert_eq!(sched.completed(), 0);

    // the late request arrives while lane 0 is still decoding
    handle.submit(b).unwrap();
    handle.close();
    let stats = sched.run().unwrap();

    assert_eq!(stats.batches_started, 1,
               "a late submission must join the running batch, not \
                restart it");
    assert_eq!(stats.responses.len(), 2);
    assert_ids_complete(&stats.responses, 2, "late admission");
    for resp in &stats.responses {
        assert_eq!(resp.tokens, want[resp.id as usize],
                   "request {} diverged after mid-decode admission",
                   resp.id);
    }
}

// ---------------------------------------------------------------------------
// graceful drain, cross-thread producer
// ---------------------------------------------------------------------------

#[test]
fn drain_on_shutdown_loses_and_duplicates_nothing() {
    let backend = serving_backend(0xD8A1);
    let (sched, handle) = Scheduler::new(&backend, SchedulerOpts {
        serve: ServeOpts { temperature: 0.8, seed: 4, max_batch: 2 },
        // a shallow queue forces the producer to block on backpressure
        // while the consumer decodes — the real async topology
        queue_depth: 3,
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: Some(2),
        ..Default::default()
    }).unwrap();

    let n = 17usize;
    let submitter = std::thread::spawn(move || {
        for i in 0..n as u64 {
            handle.submit(Request {
                id: i,
                prompt: vec![1 + (i % 7) as i32],
                n_tokens: 2 + (i % 4) as usize,
                session: None,
            }).unwrap();
        }
        handle.close();
    });
    let stats = sched.run().unwrap();
    submitter.join().unwrap();

    assert_eq!(stats.responses.len(), n);
    assert_ids_complete(&stats.responses, n, "drain");
    for r in &stats.responses {
        assert_eq!(r.tokens.len(), 2 + (r.id % 4) as usize, "req {}", r.id);
    }
    // drain-accounting invariant: every submission served or expired
    assert_eq!(stats.submitted, n);
    assert_eq!(stats.submitted,
               stats.responses.len() + stats.expired.len());
    assert_eq!(stats.admitted, n);
    assert_eq!(stats.rejected, 0);
    assert!(stats.expired.is_empty());
    assert!(stats.max_queue_depth >= 1);
    assert!(stats.max_queue_depth <= 3);
}

// ---------------------------------------------------------------------------
// run-to-completion fallback for backends without lane reset
// ---------------------------------------------------------------------------

/// A native backend masquerading as a fixed (PJRT-shaped) one: decode
/// works, but lanes cannot be re-seeded, so the scheduler must fall back
/// to admission-at-formation and run each batch to completion.
struct FixedBackend(NativeBackend);

impl Backend for FixedBackend {
    type State = NativeState;

    fn name(&self) -> &str {
        "fixed"
    }

    fn step_batches(&self) -> Vec<usize> {
        self.0.step_batches()
    }

    fn decode_state(&self, batch: usize) -> anyhow::Result<NativeState> {
        self.0.decode_state(batch)
    }

    fn decode_step(&self, x_t: &Tensor, state: NativeState)
                   -> anyhow::Result<(Tensor, NativeState)> {
        self.0.decode_step(x_t, state)
    }

    fn prefill(&self, x: &Tensor) -> anyhow::Result<(Tensor, NativeState)> {
        self.0.prefill(x)
    }

    // default reset_lane (false) and lane_reset_supported (false):
    // the run-to-completion path
}

#[test]
fn fallback_without_lane_reset_still_serves_everything() {
    let native = serving_backend(0x0F1C);
    let requests = random_requests(&mut Rng::new(55), 7);
    let want = sequential_oracle(&native, &requests);
    let backend = FixedBackend(native);

    let (sched, handle) = Scheduler::new(&backend, SchedulerOpts {
        serve: ServeOpts { temperature: 0.0, seed: 2, max_batch: 2 },
        queue_depth: requests.len(),
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: None,
        ..Default::default()
    }).unwrap();
    for req in requests.iter().cloned() {
        handle.submit(req).unwrap();
    }
    handle.close();
    let stats = sched.run().unwrap();

    assert_eq!(stats.responses.len(), requests.len());
    assert_ids_complete(&stats.responses, requests.len(), "fallback");
    // 7 requests through 2-lane run-to-completion batches: several batches
    assert!(stats.batches_started >= 4,
            "expected run-to-completion re-planning, got {} batches",
            stats.batches_started);
    for resp in &stats.responses {
        assert_eq!(resp.tokens, want[resp.id as usize],
                   "fallback: request {} diverged", resp.id);
    }
}

// ---------------------------------------------------------------------------
// deadline expiry while a decode step is in flight
// ---------------------------------------------------------------------------

#[test]
fn deadline_elapsing_mid_decode_expires_at_next_admission_pass() {
    let backend = serving_backend(0xDEAD);
    let (mut sched, handle) = Scheduler::new(&backend, SchedulerOpts {
        serve: ServeOpts { temperature: 0.0, seed: 0, max_batch: 1 },
        queue_depth: 4,
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: Some(1),
        ..Default::default()
    }).unwrap();

    // request 0 occupies the only lane for a long decode
    handle.submit(Request { id: 0, prompt: vec![1, 2], n_tokens: 16,
                            session: None }).unwrap();
    for _ in 0..4 {
        assert!(sched.step().unwrap());
    }
    assert_eq!(sched.active_lanes(), 1);
    assert_eq!(sched.completed(), 0);

    // request 1's deadline has long elapsed by the time any admission
    // pass can look at it: deadlines are only evaluated when a submission
    // is popped toward a free lane, so it waits out request 0's decode in
    // the queue and must be expired at the next admission pass — never
    // served late, never counted twice
    handle.submit_with_deadline(
        Request { id: 1, prompt: vec![3], n_tokens: 2, session: None },
        Some(std::time::Duration::ZERO)).unwrap();
    handle.close();
    let stats = sched.run().unwrap();

    assert_eq!(stats.responses.len(), 1);
    assert_eq!(stats.responses[0].id, 0);
    assert_eq!(stats.responses[0].tokens.len(), 16,
               "the in-flight request must still be served in full");
    assert_eq!(stats.expired, vec![1]);
    // expired ids never overlap response ids, and the drain-accounting
    // invariant (every submission served or expired, exactly once) holds
    assert!(stats.responses.iter()
            .all(|r| !stats.expired.contains(&r.id)));
    assert_eq!(stats.submitted,
               stats.responses.len() + stats.expired.len());
    assert_eq!(stats.tokens_generated, 16);
}
