//! The SIMD invariance contract (see `ARCHITECTURE.md`): f32 results
//! are **bit-for-bit identical** whichever dispatch level the lane
//! kernels run at — forced scalar fallback vs the runtime-detected
//! AVX2 path — and, as before, at any thread count.  Quantized (int8)
//! layers share the same guarantee across dispatch levels because the
//! dequant op sequence is identical in both kernels.
//!
//! * **env grammar** — `MINRNN_SIMD=off|scalar|0` pins the scalar
//!   fallback (`parse_level` is pure, so no env races);
//! * **dense** — odd shapes and unaligned tails through the 16-wide
//!   register tile, f32 and int8;
//! * **transcendentals** — the staged `exp` / `log1p(exp(x))` buffers
//!   the scan uses, odd lengths so the 8-lane loop plus scalar tail
//!   both run;
//! * **scan** — the chunked log-space scan end to end;
//! * **models** — full forward + decode for every mixer kind, across
//!   dispatch levels x thread counts {1, 2, 7}.
//!
//! On hardware without AVX2 the cross-level assertions degenerate to
//! scalar-vs-scalar (still run, trivially equal) — the contract is
//! only falsifiable on an AVX2 machine, which CI provides.

use std::sync::{Mutex, MutexGuard, OnceLock};

use minrnn::backend::native::linalg::Dense;
use minrnn::backend::native::{quant, scan};
use minrnn::backend::{NativeBackend, NativeInit, NativeModel, MIXER_KINDS};
use minrnn::runtime::Backend;
use minrnn::tensor::Tensor;
use minrnn::util::rng::Rng;
use minrnn::util::simd::{self, Level};
use minrnn::util::threads;

/// `set_forced` is process-global; every test that flips it holds this
/// lock so parallel test threads never observe a foreign level.
fn forced_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Run `f` with the dispatch level pinned, restoring detection after.
fn at_level<T>(lvl: Level, f: impl FnOnce() -> T) -> T {
    simd::set_forced(Some(lvl));
    let out = f();
    simd::set_forced(None);
    out
}

/// The levels this machine can actually falsify the contract at.
fn levels_here() -> Vec<Level> {
    match simd::detect_level() {
        Level::Scalar => vec![Level::Scalar],
        Level::Avx2 => vec![Level::Scalar, Level::Avx2],
    }
}

fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

// ---------------------------------------------------------------------------
// MINRNN_SIMD grammar
// ---------------------------------------------------------------------------

#[test]
fn minrnn_simd_off_pins_the_scalar_fallback() {
    for off in ["off", "OFF", "Off", "scalar", "SCALAR", "0", " off "] {
        assert_eq!(simd::parse_level(Some(off), true), Level::Scalar,
                   "MINRNN_SIMD={off:?} must force scalar");
        assert_eq!(simd::parse_level(Some(off), false), Level::Scalar);
    }
    // anything else (including unset) defers to CPU capability
    for other in [None, Some("on"), Some("1"), Some("avx2"), Some("")] {
        assert_eq!(simd::parse_level(other, true), Level::Avx2,
                   "MINRNN_SIMD={other:?} must allow dispatch");
        assert_eq!(simd::parse_level(other, false), Level::Scalar);
    }
}

#[test]
fn forcing_a_level_overrides_detection_until_cleared() {
    let _g = forced_lock();
    simd::set_forced(Some(Level::Scalar));
    assert_eq!(simd::level(), Level::Scalar);
    simd::set_forced(None);
    assert_eq!(simd::level(), simd::detect_level());
}

// ---------------------------------------------------------------------------
// dense: odd shapes + unaligned tails, f32 and int8
// ---------------------------------------------------------------------------

#[test]
fn dense_is_bit_identical_across_dispatch_levels() {
    let _g = forced_lock();
    let levels = levels_here();
    let mut rng = Rng::new(0x51AD);
    // shapes straddling the 16-wide column tile and 64-deep k tile:
    // exact fits, sub-tile, and ragged tails on both axes
    for &(rows, d_in, d_out) in &[(1usize, 1usize, 1usize), (2, 7, 5),
                                  (3, 33, 17), (1, 64, 16), (2, 65, 31),
                                  (1, 130, 48), (4, 96, 50)] {
        let w = randn(&mut rng, d_in * d_out, 0.3);
        let b = randn(&mut rng, d_out, 0.1);
        let x = randn(&mut rng, rows * d_in, 1.0);
        let f = Dense::new(d_in, d_out, w.clone(), b.clone()).unwrap();
        let mut q = Dense::new(d_in, d_out, w, b).unwrap();
        quant::quantize_dense(&mut q).unwrap();
        let outs: Vec<(Vec<f32>, Vec<f32>)> = levels.iter()
            .map(|&l| at_level(l, || (f.apply(&x, rows),
                                      q.apply(&x, rows))))
            .collect();
        for (i, other) in outs.iter().enumerate().skip(1) {
            assert_eq!(outs[0].0, other.0,
                       "f32 dense ({rows},{d_in},{d_out}) differs at \
                        level {:?}", levels[i]);
            assert_eq!(outs[0].1, other.1,
                       "int8 dense ({rows},{d_in},{d_out}) differs at \
                        level {:?}", levels[i]);
        }
    }
}

// ---------------------------------------------------------------------------
// transcendental buffers: 8-lane body + scalar tail
// ---------------------------------------------------------------------------

#[test]
fn staged_transcendentals_are_bit_identical_across_levels() {
    let _g = forced_lock();
    let levels = levels_here();
    let mut rng = Rng::new(0xE79);
    // odd lengths so both the vector body and the tail see data; include
    // the clamp edges and the -inf that the scan feeds through log1p∘exp
    for n in [1usize, 7, 8, 9, 13, 64, 67] {
        let mut base = randn(&mut rng, n, 30.0);
        base[0] = f32::NEG_INFINITY;
        if n > 2 {
            base[1] = simd::EXP_HI + 5.0;
            base[2] = simd::EXP_LO - 5.0;
        }
        let runs: Vec<(Vec<f32>, Vec<f32>)> = levels.iter().map(|&l| {
            at_level(l, || {
                let mut e = base.clone();
                simd::exp_inplace(l, &mut e);
                let mut le = base.clone();
                simd::log1p_exp_inplace(l, &mut le);
                (e, le)
            })
        }).collect();
        for (i, other) in runs.iter().enumerate().skip(1) {
            assert_eq!(runs[0].0, other.0,
                       "exp buf len {n} differs at {:?}", levels[i]);
            assert_eq!(runs[0].1, other.1,
                       "log1p∘exp buf len {n} differs at {:?}", levels[i]);
        }
        // the -inf identity the scan's seamless-chunk property rests on
        assert_eq!(runs[0].0[0], 0.0);
        assert_eq!(runs[0].1[0], 0.0);
    }
}

// ---------------------------------------------------------------------------
// log-space scan end to end
// ---------------------------------------------------------------------------

#[test]
fn log_scan_is_bit_identical_across_levels_and_threads() {
    let _g = forced_lock();
    let levels = levels_here();
    let pool = threads::global();
    let before = pool.active();
    let mut rng = Rng::new(0x5CA9);
    // odd (t, d) so chunk boundaries (64) and lane blocks (32) both have
    // ragged tails
    let (batch, t, d) = (2usize, 67usize, 19usize);
    let la: Vec<f32> = (0..batch * t * d)
        .map(|_| rng.range_f32(-3.0, 0.0)).collect();
    let lb: Vec<f32> = (0..batch * t * d)
        .map(|_| rng.range_f32(-4.0, 0.0)).collect();
    let lh0: Vec<f32> = (0..batch * d)
        .map(|_| rng.range_f32(-2.0, 0.0)).collect();
    let mut runs = Vec::new();
    for &lvl in &levels {
        for nthr in [1usize, 2, 7] {
            pool.set_active(nthr);
            let h = at_level(lvl, || scan::scan_log(&la, &lb, &lh0,
                                                    batch, t, d));
            runs.push(((lvl, nthr), h));
        }
    }
    pool.set_active(before);
    for (key, h) in &runs[1..] {
        assert_eq!(&runs[0].1, h,
                   "scan_log differs at level/threads {key:?}");
    }
}

// ---------------------------------------------------------------------------
// full models: every mixer kind x dispatch level x thread count
// ---------------------------------------------------------------------------

fn tiny_backend(kind: &str) -> NativeBackend {
    NativeBackend::new(NativeModel::init_random(&NativeInit {
        kind: kind.to_string(),
        n_layers: 2,
        d_model: 16,
        expansion: 2,
        vocab_in: Some(23),
        input_dim: None,
        vocab_out: 23,
        conv: true,
        mlp: true,
        mlp_mult: 2,
        forget_bias: 0.5,
        max_len: 32,
        n_heads: 2,
    }, 0xD15).unwrap())
}

#[test]
fn every_mixer_kind_is_bit_identical_across_levels_and_threads() {
    let _g = forced_lock();
    let levels = levels_here();
    let pool = threads::global();
    let before = pool.active();
    for &kind in MIXER_KINDS {
        let backend = tiny_backend(kind);
        let ctx = Tensor::i32(vec![2, 11], (0..22).map(|i| i % 23).collect());
        let mut runs: Vec<((Level, usize), Vec<f32>)> = Vec::new();
        for &lvl in &levels {
            for nthr in [1usize, 2, 7] {
                pool.set_active(nthr);
                let out = at_level(lvl, || {
                    // prefill logits + a few decode steps, concatenated
                    let (logits, mut state) =
                        backend.prefill(&ctx).unwrap();
                    let mut all =
                        logits.data.as_f32().unwrap().to_vec();
                    for step in 0..3 {
                        let x = Tensor::i32(vec![2],
                                            vec![step, (step + 5) % 23]);
                        let (l, s) =
                            backend.decode_step(&x, state).unwrap();
                        all.extend_from_slice(l.data.as_f32().unwrap());
                        state = s;
                    }
                    all
                });
                runs.push(((lvl, nthr), out));
            }
        }
        for (key, out) in &runs[1..] {
            assert_eq!(&runs[0].1, out,
                       "{kind}: outputs differ at level/threads {key:?}");
        }
        pool.set_active(before);
    }
}

// ---------------------------------------------------------------------------
// quantized models share the cross-level guarantee
// ---------------------------------------------------------------------------

#[test]
fn quantized_model_is_bit_identical_across_levels() {
    let _g = forced_lock();
    let levels = levels_here();
    let backend = tiny_backend("mingru");
    let mut qmodel = backend.model.clone();
    quant::quantize_model(&mut qmodel).unwrap();
    let qbackend = NativeBackend::new(qmodel);
    let ctx = Tensor::i32(vec![1, 9], (0..9).map(|i| (i * 3) % 23).collect());
    let runs: Vec<Vec<f32>> = levels.iter().map(|&lvl| {
        at_level(lvl, || {
            let (logits, _) = qbackend.prefill(&ctx).unwrap();
            logits.data.as_f32().unwrap().to_vec()
        })
    }).collect();
    for (i, other) in runs.iter().enumerate().skip(1) {
        assert_eq!(&runs[0], other,
                   "quantized model differs at {:?}", levels[i]);
    }
}
