//! Native-training correctness: finite-difference gradient checks over
//! every parameter leaf of both mixer backbones (conv/MLP on and off, the
//! continuous-input path), and an end-to-end train → checkpoint → serve
//! loop that must cut the loss at least 2x.
//!
//! The finite-difference oracle evaluates the loss through an **f64
//! mirror** of the forward pass (real-space recurrence — mathematically
//! identical to the log-space scan), so central differences at eps = 1e-5
//! measure the true directional derivative to ~1e-9 instead of drowning
//! in f32 rounding; the analytic f32 gradients from
//! `backend::native::autograd` must match to 1e-3 relative.  Directions
//! are the normalized analytic gradients — the projection that catches
//! both scale and sign errors on every leaf.

use minrnn::backend::native::{autograd, loss};
use minrnn::backend::native::linalg::CONV_K;
use minrnn::backend::native::model::{InputLayer, MixerParams, NativeModel};
use minrnn::backend::native::{NativeInit, NativeTrainer, H0_VALUE};
use minrnn::backend::NativeBackend;
use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::trainer::{run_loop, FnSource};
use minrnn::coordinator::{infer, server};
use minrnn::tensor::{Batch, Tensor};
use minrnn::util::rng::Rng;

// ---------------------------------------------------------------------------
// f64 mirror of the forward pass + loss
// ---------------------------------------------------------------------------

fn sigmoid64(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

fn softplus64(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

fn g64(x: f64) -> f64 {
    if x >= 0.0 { x + 0.5 } else { sigmoid64(x) }
}

fn silu64(x: f64) -> f64 {
    x * sigmoid64(x)
}

fn gelu64(x: f64) -> f64 {
    // the f32 kernel's constants, widened — the mirror must follow the
    // implementation, not the exact erf form
    let c = 0.797_884_56_f64;
    0.5 * x * (1.0 + (c * (x + 0.044_715 * x * x * x)).tanh())
}

fn dense64(x: &[f64], w: &[f64], b: &[f64], rows: usize, d_in: usize,
           d_out: usize) -> Vec<f64> {
    let mut y = vec![0.0; rows * d_out];
    for r in 0..rows {
        for o in 0..d_out {
            let mut acc = b[o];
            for k in 0..d_in {
                acc += x[r * d_in + k] * w[k * d_out + o];
            }
            y[r * d_out + o] = acc;
        }
    }
    y
}

fn rmsnorm64(x: &[f64], s: &[f64], rows: usize, d: usize) -> Vec<f64> {
    let mut y = vec![0.0; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for i in 0..d {
            y[r * d + i] = xr[i] * inv * s[i];
        }
    }
    y
}

fn conv64(x: &[f64], w: &[f64], b: &[f64], batch: usize, t: usize,
          d: usize, k: usize) -> Vec<f64> {
    let mut y = vec![0.0; batch * t * d];
    for bi in 0..batch {
        for ti in 0..t {
            for di in 0..d {
                let mut acc = b[di];
                for j in 0..k {
                    let src = ti as isize + j as isize - (k as isize - 1);
                    if src >= 0 {
                        acc += w[j * d + di]
                            * x[(bi * t + src as usize) * d + di];
                    }
                }
                y[(bi * t + ti) * d + di] = silu64(acc);
            }
        }
    }
    y
}

/// Sequential cursor over perturbed f64 leaves in canonical order.
struct Leaves<'a> {
    v: &'a [Vec<f64>],
    i: usize,
}

impl<'a> Leaves<'a> {
    fn pop(&mut self) -> &'a [f64] {
        self.i += 1;
        &self.v[self.i - 1]
    }
}

/// Full-model loss in f64: real-space recurrence (identical algebra to
/// the log-space scan), reading parameter values from `leaves` in
/// [`NativeModel::leaf_names`] order — `model` supplies only structure.
fn mirror_loss(model: &NativeModel, leaves: &[Vec<f64>], x: &Tensor,
               targets: &[i32], mask: &[f32]) -> f64 {
    let mut lv = Leaves { v: leaves, i: 0 };
    let (batch, t) = (x.dims[0], x.dims[1]);
    let rows = batch * t;
    let d = model.d_model;
    let mut h: Vec<f64> = match (&model.input, &x.data) {
        (InputLayer::Embed(e), minrnn::util::io::TensorData::I32(ids)) => {
            let w = lv.pop();
            let mut out = vec![0.0; rows * d];
            for (r, &id) in ids.iter().enumerate() {
                let row = (id.max(0) as usize).min(e.vocab - 1);
                out[r * d..(r + 1) * d]
                    .copy_from_slice(&w[row * d..(row + 1) * d]);
            }
            out
        }
        (InputLayer::Proj(p), minrnn::util::io::TensorData::F32(v)) => {
            let w = lv.pop();
            let b = lv.pop();
            let xf: Vec<f64> = v.iter().map(|&f| f as f64).collect();
            dense64(&xf, w, b, rows, p.d_in, d)
        }
        _ => panic!("mirror: input/x mismatch"),
    };
    for blk in &model.blocks {
        let ln1 = lv.pop();
        let u1 = rmsnorm64(&h, ln1, rows, d);
        let mixer_in = match &blk.conv {
            Some(conv) => {
                let cw = lv.pop();
                let cb = lv.pop();
                conv64(&u1, cw, cb, batch, t, d, conv.k)
            }
            None => u1,
        };
        let dh = blk.mixer.d_hidden();
        // recurrence h_t = a ⊙ h_{t-1} + b, h_0 = g(0) = 0.5
        let mut hseq = vec![0.0; rows * dh];
        match &blk.mixer {
            MixerParams::MinGru(_) => {
                let wz = lv.pop();
                let bz = lv.pop();
                let wh = lv.pop();
                let bh = lv.pop();
                let k = dense64(&mixer_in, wz, bz, rows, d, dh);
                let pre = dense64(&mixer_in, wh, bh, rows, d, dh);
                for bi in 0..batch {
                    for di in 0..dh {
                        let mut v = H0_VALUE as f64;
                        for ti in 0..t {
                            let o = (bi * t + ti) * dh + di;
                            let z = sigmoid64(k[o]);
                            v = (1.0 - z) * v + z * g64(pre[o]);
                            hseq[o] = v;
                        }
                    }
                }
            }
            MixerParams::MinLstm(_) => {
                let wf = lv.pop();
                let bf = lv.pop();
                let wi = lv.pop();
                let bi_ = lv.pop();
                let wh = lv.pop();
                let bh = lv.pop();
                let f = dense64(&mixer_in, wf, bf, rows, d, dh);
                let k = dense64(&mixer_in, wi, bi_, rows, d, dh);
                let pre = dense64(&mixer_in, wh, bh, rows, d, dh);
                for bi in 0..batch {
                    for di in 0..dh {
                        let mut v = H0_VALUE as f64;
                        for ti in 0..t {
                            let o = (bi * t + ti) * dh + di;
                            let diff = softplus64(-f[o]) - softplus64(-k[o]);
                            let fp = sigmoid64(-diff);
                            let ip = sigmoid64(diff);
                            v = fp * v + ip * g64(pre[o]);
                            hseq[o] = v;
                        }
                    }
                }
            }
        }
        let wd = lv.pop();
        let bd = lv.pop();
        let y = dense64(&hseq, wd, bd, rows, dh, d);
        for (hv, yv) in h.iter_mut().zip(&y) {
            *hv += yv;
        }
        if let (Some(_), Some(mlp)) = (&blk.ln2, &blk.mlp) {
            let ln2 = lv.pop();
            let u2 = rmsnorm64(&h, ln2, rows, d);
            let uw = lv.pop();
            let ub = lv.pop();
            let mut hid = dense64(&u2, uw, ub, rows, d, mlp.up.d_out);
            for v in hid.iter_mut() {
                *v = gelu64(*v);
            }
            let dw = lv.pop();
            let db = lv.pop();
            let z = dense64(&hid, dw, db, rows, mlp.up.d_out, d);
            for (hv, zv) in h.iter_mut().zip(&z) {
                *hv += zv;
            }
        }
    }
    let ln_f = lv.pop();
    let uf = rmsnorm64(&h, ln_f, rows, d);
    let hw = lv.pop();
    let hb = lv.pop();
    let v = model.vocab_out;
    let logits = dense64(&uf, hw, hb, rows, d, v);
    assert_eq!(lv.i, leaves.len(), "mirror consumed {} of {} leaves",
               lv.i, leaves.len());

    // masked CE in f64
    let msum: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    let mut lsum = 0.0;
    for r in 0..rows {
        let w = mask[r] as f64;
        if w == 0.0 {
            continue;
        }
        let row = &logits[r * v..(r + 1) * v];
        let rmax = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = rmax
            + row.iter().map(|&l| (l - rmax).exp()).sum::<f64>().ln();
        lsum += w * (lse - row[targets[r] as usize]);
    }
    lsum / msum
}

// ---------------------------------------------------------------------------
// gradient checks
// ---------------------------------------------------------------------------

struct Case {
    kind: &'static str,
    conv: bool,
    mlp: bool,
    /// None → token embedding input; Some(f) → continuous features.
    input_dim: Option<usize>,
}

fn grad_check(case: &Case, seed: u64) {
    let vocab = 11usize;
    let model = NativeModel::init_random(&NativeInit {
        kind: case.kind.to_string(),
        n_layers: 2,
        d_model: 6,
        expansion: 2,
        vocab_in: if case.input_dim.is_some() { None } else { Some(vocab) },
        input_dim: case.input_dim,
        vocab_out: vocab,
        conv: case.conv,
        mlp: case.mlp,
        mlp_mult: 2,
        forget_bias: 1.0,
    }, seed).unwrap();
    let (batch, t) = (2usize, 6usize);
    let mut rng = Rng::new(seed ^ 0xFD);
    let x = match case.input_dim {
        None => Tensor::i32(vec![batch, t],
                            (0..batch * t)
                                .map(|_| rng.below(vocab as u64) as i32)
                                .collect()),
        Some(f) => Tensor::f32(vec![batch, t, f],
                               (0..batch * t * f)
                                   .map(|_| rng.normal_f32(0.0, 1.0))
                                   .collect()),
    };
    let targets: Vec<i32> = (0..batch * t)
        .map(|_| rng.below(vocab as u64) as i32).collect();
    let mut mask: Vec<f32> = (0..batch * t)
        .map(|_| if rng.f32() < 0.8 { 1.0 } else { 0.0 }).collect();
    mask[0] = 1.0;

    // analytic gradients (f32 pipeline under test)
    let tape = autograd::forward(&model, &x).unwrap();
    let mut dlogits = Vec::new();
    let metrics = loss::masked_ce(&tape.logits, &targets, &mask, batch, t,
                                  vocab, Some(&mut dlogits)).unwrap();
    let mut grads = model.zeros_like();
    autograd::backward(&model, &tape, &x, &dlogits, &mut grads).unwrap();

    // f64 parameter copies for the mirror
    let base: Vec<Vec<f64>> = model.leaves().iter()
        .map(|l| l.iter().map(|&v| v as f64).collect()).collect();
    let l0 = mirror_loss(&model, &base, &x, &targets, &mask);
    assert!((l0 - metrics.loss as f64).abs() < 1e-4 * l0.max(1.0),
            "{}: mirror loss {l0} vs f32 pipeline {}", case.kind,
            metrics.loss);

    let names = model.leaf_names();
    let gleaves = grads.leaves();
    let eps = 1e-5f64;
    for (li, (name, gleaf)) in names.iter().zip(&gleaves).enumerate() {
        let gnorm = gleaf.iter()
            .map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
        assert!(gnorm > 1e-8,
                "{} conv={} mlp={}: leaf '{name}' has ~zero gradient",
                case.kind, case.conv, case.mlp);
        let u: Vec<f64> = gleaf.iter().map(|&g| g as f64 / gnorm).collect();
        let mut plus = base.clone();
        let mut minus = base.clone();
        for (j, &uj) in u.iter().enumerate() {
            plus[li][j] += eps * uj;
            minus[li][j] -= eps * uj;
        }
        let lp = mirror_loss(&model, &plus, &x, &targets, &mask);
        let lm = mirror_loss(&model, &minus, &x, &targets, &mask);
        let num = (lp - lm) / (2.0 * eps);
        let rel = (num - gnorm).abs() / gnorm.max(num.abs()).max(1e-4);
        assert!(rel <= 1e-3,
                "{} conv={} mlp={} leaf '{name}': analytic {gnorm:.6e} vs \
                 finite-difference {num:.6e} (rel {rel:.2e} > 1e-3)",
                case.kind, case.conv, case.mlp);
    }
}

#[test]
fn grad_check_mingru_all_architectures() {
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "mingru", conv, mlp, input_dim: None },
                   100 + i as u64);
    }
}

#[test]
fn grad_check_minlstm_all_architectures() {
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "minlstm", conv, mlp, input_dim: None },
                   200 + i as u64);
    }
}

#[test]
fn grad_check_continuous_input_projection() {
    // the in_proj (RL-style features) path has its own backward
    grad_check(&Case { kind: "mingru", conv: false, mlp: false,
                       input_dim: Some(3) }, 300);
    grad_check(&Case { kind: "minlstm", conv: true, mlp: true,
                       input_dim: Some(4) }, 301);
}

// ---------------------------------------------------------------------------
// end-to-end: native train → checkpoint → native serve
// ---------------------------------------------------------------------------

fn echo_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Batch {
    let x: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32)
        .collect();
    Batch {
        targets: Tensor::i32(vec![b, t], x.clone()),
        x: Tensor::i32(vec![b, t], x),
        mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
    }
}

#[test]
fn native_train_then_serve_cuts_loss_2x() {
    let vocab = 12usize;
    let model = NativeModel::init_random(&NativeInit {
        kind: "minlstm".to_string(),
        d_model: 16,
        n_layers: 1,
        vocab_in: Some(vocab),
        vocab_out: vocab,
        forget_bias: 1.0,
        ..Default::default()
    }, 21).unwrap();
    let mut trainer = NativeTrainer::new(model, "e2e-echo");
    let dir = std::env::temp_dir().join("minrnn_train_props_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TrainConfig {
        steps: 80,
        lr: 5e-3,
        schedule: Schedule::Constant,
        seed: 5,
        eval_every: 40,
        eval_batches: 2,
        log_every: 1000, // keep test output quiet
        checkpoint: Some(dir.clone()),
        ..Default::default()
    };
    let mut data = FnSource {
        f: move |rng: &mut Rng| echo_batch(rng, 8, 12, vocab),
    };
    let report = run_loop(&mut trainer, &cfg, 0, &mut data).unwrap();
    let (first_step, first_loss) = report.loss_curve[0];
    assert_eq!(first_step, 0);
    // the paper-level acceptance bar: >= 2x loss reduction from init
    assert!(report.final_loss < first_loss / 2.0,
            "loss {} -> {} is not a 2x drop", first_loss,
            report.final_loss);
    let eval = report.final_eval.expect("eval ran");
    assert!(eval.token_acc > 0.5,
            "echo task should be mostly learned, token_acc {}",
            eval.token_acc);

    // round-trip the best checkpoint into native inference and serve
    let ckpt = dir.join("e2e-echo.best.ckpt");
    assert!(ckpt.exists(), "best checkpoint written");
    let backend = NativeBackend::from_checkpoint(&ckpt).unwrap();
    let mut rng = Rng::new(0);
    let out = infer::generate(&backend, &[1, 2, 3], 8, 0.0, &mut rng)
        .unwrap();
    assert_eq!(out.len(), 8);
    // a well-trained echo model greedily repeats its last input token
    assert!(out.iter().all(|&tok| (0..vocab as i32).contains(&tok)));
    let stats = server::serve(&backend, (0..4).map(|i| server::Request {
        id: i,
        prompt: vec![(i % vocab as u64) as i32 + 1, 2],
        n_tokens: 4,
    }).collect(), 0.5, 1).unwrap();
    assert_eq!(stats.responses.len(), 4);
    assert!(stats.responses.iter().all(|r| r.tokens.len() == 4));

    // the final checkpoint also restores a resumable trainer
    let resumed = NativeTrainer::from_checkpoint(
        &dir.join("e2e-echo.final.ckpt"), "e2e-echo").unwrap();
    assert_eq!(resumed.step(), report.steps_run as u64);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trained_checkpoint_is_pjrt_shaped() {
    // the checkpoint a native training run writes uses the same params/
    // leaf naming the AOT manifest path uses, so it loads back through
    // from_named without translation — and CONV_K pins the conv layout
    let model = NativeModel::init_random(&NativeInit {
        conv: true,
        mlp: true,
        vocab_in: Some(8),
        vocab_out: 8,
        d_model: 8,
        n_layers: 1,
        ..Default::default()
    }, 3).unwrap();
    let trainer = NativeTrainer::new(model, "shape");
    let named = trainer.model.to_named();
    let names: Vec<&str> = named.iter().map(|t| t.name.as_str()).collect();
    assert!(names.contains(&"params/blocks/0/mixer/linear_z/w"));
    assert!(names.contains(&"params/blocks/0/conv/w"));
    let conv = named.iter()
        .find(|t| t.name == "params/blocks/0/conv/w").unwrap();
    assert_eq!(conv.dims, vec![CONV_K, 8]);
    let back = NativeModel::from_named(&named).unwrap();
    assert_eq!(back.leaf_names(), trainer.model.leaf_names());
}
