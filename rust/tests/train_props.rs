//! Native-training correctness: finite-difference gradient checks over
//! every parameter leaf of both mixer backbones (conv/MLP on and off, the
//! continuous-input path, all three heads, dropout on and off), dropout
//! determinism properties, and end-to-end train → checkpoint → serve
//! loops per head.
//!
//! The finite-difference oracle evaluates the loss through an **f64
//! mirror** of the forward pass (real-space recurrence — mathematically
//! identical to the log-space scan), so central differences at eps = 1e-5
//! measure the true directional derivative to ~1e-9 instead of drowning
//! in f32 rounding; the analytic f32 gradients from
//! `backend::native::autograd` must match to 1e-3 relative.  Directions
//! are the normalized analytic gradients — the projection that catches
//! both scale and sign errors on every leaf.  Dropout masks are a pure
//! function of `(drop_seed, stream, index)` via
//! `autograd::drop_multiplier`, so the mirror applies the exact masks the
//! f32 pipeline drew.

use minrnn::backend::native::{autograd, loss};
use minrnn::backend::native::autograd::drop_multiplier;
use minrnn::backend::native::linalg::CONV_K;
use minrnn::backend::native::model::{InputLayer, MixerParams, NativeModel};
use minrnn::backend::native::{Head, NativeInit, NativeTrainer, H0_VALUE};
use minrnn::backend::NativeBackend;
use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::trainer::{run_loop, FnSource};
use minrnn::coordinator::{infer, server};
use minrnn::data::lra;
use minrnn::data::rl::{OfflineDataset, Regime};
use minrnn::tensor::{Batch, Tensor};
use minrnn::util::rng::Rng;
use minrnn::util::threads;

// ---------------------------------------------------------------------------
// f64 mirror of the forward pass + losses
// ---------------------------------------------------------------------------

fn sigmoid64(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

fn softplus64(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

fn g64(x: f64) -> f64 {
    if x >= 0.0 { x + 0.5 } else { sigmoid64(x) }
}

fn silu64(x: f64) -> f64 {
    x * sigmoid64(x)
}

fn gelu64(x: f64) -> f64 {
    // the f32 kernel's constants, widened — the mirror must follow the
    // implementation, not the exact erf form
    let c = 0.797_884_56_f64;
    0.5 * x * (1.0 + (c * (x + 0.044_715 * x * x * x)).tanh())
}

fn dense64(x: &[f64], w: &[f64], b: &[f64], rows: usize, d_in: usize,
           d_out: usize) -> Vec<f64> {
    let mut y = vec![0.0; rows * d_out];
    for r in 0..rows {
        for o in 0..d_out {
            let mut acc = b[o];
            for k in 0..d_in {
                acc += x[r * d_in + k] * w[k * d_out + o];
            }
            y[r * d_out + o] = acc;
        }
    }
    y
}

fn rmsnorm64(x: &[f64], s: &[f64], rows: usize, d: usize) -> Vec<f64> {
    let mut y = vec![0.0; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for i in 0..d {
            y[r * d + i] = xr[i] * inv * s[i];
        }
    }
    y
}

fn conv64(x: &[f64], w: &[f64], b: &[f64], batch: usize, t: usize,
          d: usize, k: usize) -> Vec<f64> {
    let mut y = vec![0.0; batch * t * d];
    for bi in 0..batch {
        for ti in 0..t {
            for di in 0..d {
                let mut acc = b[di];
                for j in 0..k {
                    let src = ti as isize + j as isize - (k as isize - 1);
                    if src >= 0 {
                        acc += w[j * d + di]
                            * x[(bi * t + src as usize) * d + di];
                    }
                }
                y[(bi * t + ti) * d + di] = silu64(acc);
            }
        }
    }
    y
}

/// Sequential cursor over perturbed f64 leaves in canonical order.
struct Leaves<'a> {
    v: &'a [Vec<f64>],
    i: usize,
}

impl<'a> Leaves<'a> {
    fn pop(&mut self) -> &'a [f64] {
        self.i += 1;
        &self.v[self.i - 1]
    }
}

/// Full-model logits in f64: real-space recurrence (identical algebra to
/// the log-space scan), reading parameter values from `leaves` in
/// [`NativeModel::leaf_names`] order — `model` supplies only structure.
/// `drop`: the `(rate, seed)` of the training forward under test; masks
/// come from the same [`drop_multiplier`] the f32 pipeline uses, applied
/// to the same residual branches.
fn mirror_logits(model: &NativeModel, leaves: &[Vec<f64>], x: &Tensor,
                 drop: Option<(f32, i32)>) -> Vec<f64> {
    let mut lv = Leaves { v: leaves, i: 0 };
    let (batch, t) = (x.dims[0], x.dims[1]);
    let rows = batch * t;
    let d = model.d_model;
    let mut h: Vec<f64> = match (&model.input, &x.data) {
        (InputLayer::Embed(e), minrnn::util::io::TensorData::I32(ids)) => {
            let w = lv.pop();
            let mut out = vec![0.0; rows * d];
            for (r, &id) in ids.iter().enumerate() {
                let row = (id.max(0) as usize).min(e.vocab - 1);
                out[r * d..(r + 1) * d]
                    .copy_from_slice(&w[row * d..(row + 1) * d]);
            }
            out
        }
        (InputLayer::Proj(p), minrnn::util::io::TensorData::F32(v)) => {
            let w = lv.pop();
            let b = lv.pop();
            let xf: Vec<f64> = v.iter().map(|&f| f as f64).collect();
            dense64(&xf, w, b, rows, p.d_in, d)
        }
        _ => panic!("mirror: input/x mismatch"),
    };
    // learned positional table (transformer backbones) rides right after
    // the input leaves in canonical order
    if model.pos.is_some() {
        let pw = lv.pop();
        let pl = pw.len() / d;
        for bi in 0..batch {
            for ti in 0..t {
                let row = ti.min(pl - 1);
                for i in 0..d {
                    h[(bi * t + ti) * d + i] += pw[row * d + i];
                }
            }
        }
    }
    let drop64 = |v: &mut [f64], stream: u64| {
        if let Some((rate, seed)) = drop {
            if rate > 0.0 {
                for (i, x) in v.iter_mut().enumerate() {
                    *x *= drop_multiplier(seed, stream, i as u64,
                                          rate) as f64;
                }
            }
        }
    };
    for (li, blk) in model.blocks.iter().enumerate() {
        let ln1 = lv.pop();
        let u1 = rmsnorm64(&h, ln1, rows, d);
        let mixer_in = match &blk.conv {
            Some(conv) => {
                let cw = lv.pop();
                let cb = lv.pop();
                conv64(&u1, cw, cb, batch, t, d, conv.k)
            }
            None => u1,
        };
        let dh = blk.mixer.d_hidden();
        let mut y = match &blk.mixer {
            // recurrence h_t = a ⊙ h_{t-1} + b, h_0 = g(0) = 0.5
            MixerParams::MinGru(_) => {
                let wz = lv.pop();
                let bz = lv.pop();
                let wh = lv.pop();
                let bh = lv.pop();
                let k = dense64(&mixer_in, wz, bz, rows, d, dh);
                let pre = dense64(&mixer_in, wh, bh, rows, d, dh);
                let mut hseq = vec![0.0; rows * dh];
                for bi in 0..batch {
                    for di in 0..dh {
                        let mut v = H0_VALUE as f64;
                        for ti in 0..t {
                            let o = (bi * t + ti) * dh + di;
                            let z = sigmoid64(k[o]);
                            v = (1.0 - z) * v + z * g64(pre[o]);
                            hseq[o] = v;
                        }
                    }
                }
                let wd = lv.pop();
                let bd = lv.pop();
                dense64(&hseq, wd, bd, rows, dh, d)
            }
            MixerParams::MinLstm(_) => {
                let wf = lv.pop();
                let bf = lv.pop();
                let wi = lv.pop();
                let bi_ = lv.pop();
                let wh = lv.pop();
                let bh = lv.pop();
                let f = dense64(&mixer_in, wf, bf, rows, d, dh);
                let k = dense64(&mixer_in, wi, bi_, rows, d, dh);
                let pre = dense64(&mixer_in, wh, bh, rows, d, dh);
                let mut hseq = vec![0.0; rows * dh];
                for bi in 0..batch {
                    for di in 0..dh {
                        let mut v = H0_VALUE as f64;
                        for ti in 0..t {
                            let o = (bi * t + ti) * dh + di;
                            let diff = softplus64(-f[o]) - softplus64(-k[o]);
                            let fp = sigmoid64(-diff);
                            let ip = sigmoid64(diff);
                            v = fp * v + ip * g64(pre[o]);
                            hseq[o] = v;
                        }
                    }
                }
                let wd = lv.pop();
                let bd = lv.pop();
                dense64(&hseq, wd, bd, rows, dh, d)
            }
            // selective scan: Δ = softplus(dt(x)), a = exp(-Δ·exp(a_log)),
            // h_t = a ⊙ h_{t-1} + Δ ⊙ b(x), y = down(h ⊙ silu(gate(x)))
            MixerParams::S6Lite(_) => {
                let wdt = lv.pop();
                let bdt = lv.pop();
                let wb = lv.pop();
                let bb = lv.pop();
                let wg = lv.pop();
                let bg = lv.pop();
                let wd = lv.pop();
                let bd = lv.pop();
                let a_log = lv.pop();
                let dt = dense64(&mixer_in, wdt, bdt, rows, d, dh);
                let bx = dense64(&mixer_in, wb, bb, rows, d, dh);
                let gp = dense64(&mixer_in, wg, bg, rows, d, dh);
                let mut gated = vec![0.0; rows * dh];
                for bi in 0..batch {
                    for di in 0..dh {
                        let mut v = 0.0f64;
                        for ti in 0..t {
                            let o = (bi * t + ti) * dh + di;
                            let delta = softplus64(dt[o]);
                            let a = (-delta * a_log[di].exp()).exp();
                            v = a * v + delta * bx[o];
                            gated[o] = v * silu64(gp[o]);
                        }
                    }
                }
                dense64(&gated, wd, bd, rows, dh, d)
            }
            // causal multi-head attention over the fused qkv projection
            MixerParams::Transformer(m) => {
                let wq = lv.pop();
                let bq = lv.pop();
                let wp = lv.pop();
                let bp = lv.pop();
                let qkv = dense64(&mixer_in, wq, bq, rows, d, 3 * d);
                let hh = m.n_heads;
                let hd = d / hh;
                let scale = 1.0 / (hd as f64).sqrt();
                let mut ctx = vec![0.0; rows * d];
                for bi in 0..batch {
                    for hi in 0..hh {
                        for ti in 0..t {
                            let q = &qkv[(bi * t + ti) * 3 * d + hi * hd..]
                                [..hd];
                            let mut sc = vec![0.0f64; ti + 1];
                            for (tj, s) in sc.iter_mut().enumerate() {
                                let k = &qkv[(bi * t + tj) * 3 * d + d
                                             + hi * hd..][..hd];
                                *s = (0..hd).map(|u| q[u] * k[u])
                                    .sum::<f64>() * scale;
                            }
                            let mx = sc.iter().cloned()
                                .fold(f64::NEG_INFINITY, f64::max);
                            let mut denom = 0.0;
                            for s in sc.iter_mut() {
                                *s = (*s - mx).exp();
                                denom += *s;
                            }
                            for (tj, s) in sc.iter().enumerate() {
                                let p = s / denom;
                                let v = &qkv[(bi * t + tj) * 3 * d + 2 * d
                                             + hi * hd..][..hd];
                                for u in 0..hd {
                                    ctx[(bi * t + ti) * d + hi * hd + u] +=
                                        p * v[u];
                                }
                            }
                        }
                    }
                }
                dense64(&ctx, wp, bp, rows, d, d)
            }
        };
        drop64(&mut y, 2 * li as u64);
        for (hv, yv) in h.iter_mut().zip(&y) {
            *hv += yv;
        }
        if let (Some(_), Some(mlp)) = (&blk.ln2, &blk.mlp) {
            let ln2 = lv.pop();
            let u2 = rmsnorm64(&h, ln2, rows, d);
            let uw = lv.pop();
            let ub = lv.pop();
            let mut hid = dense64(&u2, uw, ub, rows, d, mlp.up.d_out);
            for v in hid.iter_mut() {
                *v = gelu64(*v);
            }
            let dw = lv.pop();
            let db = lv.pop();
            let mut z = dense64(&hid, dw, db, rows, mlp.up.d_out, d);
            drop64(&mut z, 2 * li as u64 + 1);
            for (hv, zv) in h.iter_mut().zip(&z) {
                *hv += zv;
            }
        }
    }
    let ln_f = lv.pop();
    let uf = rmsnorm64(&h, ln_f, rows, d);
    let hw = lv.pop();
    let hb = lv.pop();
    let logits = dense64(&uf, hw, hb, rows, d, model.vocab_out);
    assert_eq!(lv.i, leaves.len(), "mirror consumed {} of {} leaves",
               lv.i, leaves.len());
    logits
}

/// Per-head targets for a gradient-check case.
enum HeadData {
    Ce { targets: Vec<i32> },
    Mse { targets: Vec<f32> },
    Cls { targets: Vec<i32> },
}

/// The head's loss over mirror logits, in f64 — one function per head,
/// matching the fused f32 implementations' math exactly.
fn mirror_loss(logits: &[f64], data: &HeadData, mask: &[f32],
               batch: usize, t: usize, v: usize) -> f64 {
    let rows = batch * t;
    match data {
        HeadData::Ce { targets } => {
            let msum: f64 = mask.iter().map(|&m| m as f64).sum::<f64>()
                .max(1.0);
            let mut lsum = 0.0;
            for r in 0..rows {
                let w = mask[r] as f64;
                if w == 0.0 {
                    continue;
                }
                let row = &logits[r * v..(r + 1) * v];
                let rmax = row.iter().cloned()
                    .fold(f64::NEG_INFINITY, f64::max);
                let lse = rmax + row.iter().map(|&l| (l - rmax).exp())
                    .sum::<f64>().ln();
                lsum += w * (lse - row[targets[r] as usize]);
            }
            lsum / msum
        }
        HeadData::Mse { targets } => {
            let msum: f64 = mask.iter().map(|&m| m as f64).sum::<f64>()
                .max(1.0);
            let mut lsum = 0.0;
            for r in 0..rows {
                let w = mask[r] as f64;
                if w == 0.0 {
                    continue;
                }
                let mut se = 0.0;
                for a in 0..v {
                    let e = logits[r * v + a] - targets[r * v + a] as f64;
                    se += e * e;
                }
                lsum += w * se;
            }
            lsum / msum
        }
        HeadData::Cls { targets } => {
            let mut lsum = 0.0;
            let mut b_m = 0usize;
            for bi in 0..batch {
                let w_b: f64 = (0..t)
                    .map(|ti| mask[bi * t + ti] as f64).sum();
                if w_b <= 0.0 {
                    continue;
                }
                b_m += 1;
                let mut pool = vec![0.0f64; v];
                let mut label = None;
                for ti in 0..t {
                    let r = bi * t + ti;
                    let w = mask[r] as f64 / w_b;
                    if w > 0.0 {
                        label.get_or_insert(targets[r] as usize);
                        for (p, &l) in pool.iter_mut()
                            .zip(&logits[r * v..(r + 1) * v]) {
                            *p += w * l;
                        }
                    }
                }
                let pmax = pool.iter().cloned()
                    .fold(f64::NEG_INFINITY, f64::max);
                let lse = pmax + pool.iter().map(|&p| (p - pmax).exp())
                    .sum::<f64>().ln();
                lsum += lse - pool[label.unwrap()];
            }
            lsum / (b_m as f64).max(1.0)
        }
    }
}

// ---------------------------------------------------------------------------
// gradient checks
// ---------------------------------------------------------------------------

struct Case {
    kind: &'static str,
    conv: bool,
    mlp: bool,
    /// None → token embedding input; Some(f) → continuous features.
    input_dim: Option<usize>,
    /// `(rate, drop_seed)` of the training forward, if dropout is on.
    drop: Option<(f32, i32)>,
}

fn grad_check(case: &Case, head: Head, seed: u64) {
    // out_dim: vocabulary for the discrete heads, action dim for MSE
    let out = if head == Head::MaskedMse { 4usize } else { 11usize };
    let model = NativeModel::init_random(&NativeInit {
        kind: case.kind.to_string(),
        n_layers: 2,
        d_model: 6,
        expansion: 2,
        vocab_in: if case.input_dim.is_some() { None } else { Some(out) },
        input_dim: case.input_dim,
        vocab_out: out,
        conv: case.conv,
        mlp: case.mlp,
        mlp_mult: 2,
        forget_bias: 1.0,
        max_len: 16, // covers t = 6 below
        n_heads: 2,  // must divide d_model = 6
    }, seed).unwrap();
    let (batch, t) = (2usize, 6usize);
    let mut rng = Rng::new(seed ^ 0xFD);
    let x = match case.input_dim {
        None => Tensor::i32(vec![batch, t],
                            (0..batch * t)
                                .map(|_| rng.below(out as u64) as i32)
                                .collect()),
        Some(f) => Tensor::f32(vec![batch, t, f],
                               (0..batch * t * f)
                                   .map(|_| rng.normal_f32(0.0, 1.0))
                                   .collect()),
    };
    let mut mask: Vec<f32> = (0..batch * t)
        .map(|_| if rng.f32() < 0.8 { 1.0 } else { 0.0 }).collect();
    mask[0] = 1.0;
    let data = match head {
        Head::MaskedCe => HeadData::Ce {
            targets: (0..batch * t)
                .map(|_| rng.below(out as u64) as i32).collect(),
        },
        Head::MaskedMse => HeadData::Mse {
            targets: (0..batch * t * out)
                .map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        },
        Head::SeqClassify => {
            // pooled: two masked positions per sequence, same label
            let mut targets = vec![0i32; batch * t];
            for bi in 0..batch {
                let label = rng.below(out as u64) as i32;
                mask[bi * t..(bi + 1) * t].fill(0.0);
                mask[bi * t + t - 1] = 1.0;
                mask[bi * t + t - 3] = 0.5;
                targets[bi * t + t - 1] = label;
                targets[bi * t + t - 3] = label;
            }
            HeadData::Cls { targets }
        }
    };

    // analytic gradients (f32 pipeline under test)
    let (rate, dseed) = case.drop.unwrap_or((0.0, 0));
    let tape = autograd::forward_train(&model, &x, rate, dseed).unwrap();
    let mut dlogits = Vec::new();
    let metrics = match &data {
        HeadData::Ce { targets } => loss::masked_ce(
            &tape.logits, targets, &mask, batch, t, out,
            Some(&mut dlogits)),
        HeadData::Mse { targets } => loss::masked_mse(
            &tape.logits, targets, &mask, batch, t, out,
            Some(&mut dlogits)),
        HeadData::Cls { targets } => loss::seq_ce(
            &tape.logits, targets, &mask, batch, t, out,
            Some(&mut dlogits)),
    }.unwrap();
    let mut grads = model.zeros_like();
    autograd::backward(&model, &tape, &x, &dlogits, &mut grads).unwrap();

    // f64 parameter copies for the mirror
    let base: Vec<Vec<f64>> = model.leaves().iter()
        .map(|l| l.iter().map(|&v| v as f64).collect()).collect();
    let eval = |leaves: &[Vec<f64>]| -> f64 {
        let logits = mirror_logits(&model, leaves, &x, case.drop);
        mirror_loss(&logits, &data, &mask, batch, t, out)
    };
    let l0 = eval(&base);
    assert!((l0 - metrics.loss as f64).abs() < 1e-4 * l0.abs().max(1.0),
            "{} {head:?}: mirror loss {l0} vs f32 pipeline {}", case.kind,
            metrics.loss);

    let names = model.leaf_names();
    let gleaves = grads.leaves();
    let eps = 1e-5f64;
    for (li, (name, gleaf)) in names.iter().zip(&gleaves).enumerate() {
        let gnorm = gleaf.iter()
            .map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
        assert!(gnorm > 1e-8,
                "{} {head:?} conv={} mlp={}: leaf '{name}' has ~zero \
                 gradient", case.kind, case.conv, case.mlp);
        let u: Vec<f64> = gleaf.iter().map(|&g| g as f64 / gnorm).collect();
        let mut plus = base.clone();
        let mut minus = base.clone();
        for (j, &uj) in u.iter().enumerate() {
            plus[li][j] += eps * uj;
            minus[li][j] -= eps * uj;
        }
        let num = (eval(&plus) - eval(&minus)) / (2.0 * eps);
        let rel = (num - gnorm).abs() / gnorm.max(num.abs()).max(1e-4);
        assert!(rel <= 1e-3,
                "{} {head:?} conv={} mlp={} leaf '{name}': analytic \
                 {gnorm:.6e} vs finite-difference {num:.6e} \
                 (rel {rel:.2e} > 1e-3)",
                case.kind, case.conv, case.mlp);
    }
}

#[test]
fn grad_check_mingru_all_architectures() {
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "mingru", conv, mlp, input_dim: None,
                           drop: None }, Head::MaskedCe, 100 + i as u64);
    }
}

#[test]
fn grad_check_minlstm_all_architectures() {
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "minlstm", conv, mlp, input_dim: None,
                           drop: None }, Head::MaskedCe, 200 + i as u64);
    }
}

#[test]
fn grad_check_s6lite_all_architectures() {
    // the selective-scan VJP (input-dependent decay, a_log accumulation,
    // the gated output path) across the same architecture matrix
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "s6lite", conv, mlp, input_dim: None,
                           drop: None }, Head::MaskedCe, 700 + i as u64);
    }
}

#[test]
fn grad_check_transformer_all_architectures() {
    // the attention VJP (softmax, fused qkv, the learned positional
    // table's scatter-add) across the same architecture matrix
    for (i, &(conv, mlp)) in [(false, false), (true, true), (true, false),
                              (false, true)].iter().enumerate() {
        grad_check(&Case { kind: "transformer", conv, mlp, input_dim: None,
                           drop: None }, Head::MaskedCe, 800 + i as u64);
    }
}

#[test]
fn grad_check_continuous_input_projection() {
    // the in_proj (RL-style features) path has its own backward
    grad_check(&Case { kind: "mingru", conv: false, mlp: false,
                       input_dim: Some(3), drop: None }, Head::MaskedCe,
               300);
    grad_check(&Case { kind: "minlstm", conv: true, mlp: true,
                       input_dim: Some(4), drop: None }, Head::MaskedCe,
               301);
}

#[test]
fn grad_check_masked_mse_head() {
    // the RL regression head, over the continuous-input backbone
    grad_check(&Case { kind: "mingru", conv: false, mlp: true,
                       input_dim: Some(3), drop: None }, Head::MaskedMse,
               400);
    grad_check(&Case { kind: "minlstm", conv: true, mlp: true,
                       input_dim: Some(4), drop: None }, Head::MaskedMse,
               401);
}

#[test]
fn grad_check_seq_classify_head() {
    // the pooled classification head (LRA), with genuine multi-position
    // pooling in the mask
    grad_check(&Case { kind: "mingru", conv: true, mlp: true,
                       input_dim: None, drop: None }, Head::SeqClassify,
               500);
    grad_check(&Case { kind: "minlstm", conv: false, mlp: false,
                       input_dim: None, drop: None }, Head::SeqClassify,
               501);
}

#[test]
fn grad_check_with_dropout() {
    // dropout masks enter both the forward and the VJP; the mirror draws
    // the identical masks from drop_multiplier — every head, both mixers
    grad_check(&Case { kind: "mingru", conv: true, mlp: true,
                       input_dim: None, drop: Some((0.35, 77)) },
               Head::MaskedCe, 600);
    grad_check(&Case { kind: "minlstm", conv: false, mlp: true,
                       input_dim: None, drop: Some((0.25, 78)) },
               Head::MaskedCe, 601);
    grad_check(&Case { kind: "minlstm", conv: true, mlp: true,
                       input_dim: Some(4), drop: Some((0.2, 79)) },
               Head::MaskedMse, 602);
    grad_check(&Case { kind: "mingru", conv: false, mlp: true,
                       input_dim: None, drop: Some((0.3, 80)) },
               Head::SeqClassify, 603);
    grad_check(&Case { kind: "s6lite", conv: false, mlp: true,
                       input_dim: None, drop: Some((0.15, 81)) },
               Head::MaskedCe, 604);
    grad_check(&Case { kind: "transformer", conv: true, mlp: true,
                       input_dim: None, drop: Some((0.15, 82)) },
               Head::MaskedCe, 605);
}

// ---------------------------------------------------------------------------
// dropout determinism properties
// ---------------------------------------------------------------------------

fn dropout_prop_model(seed: u64) -> (NativeModel, Tensor, Vec<i32>,
                                     Vec<f32>) {
    // sized so rows·d ≥ the parallel-dispatch threshold: the pooled
    // (chunked) dropout path must run, not just the inline one
    let vocab = 9usize;
    let model = NativeModel::init_random(&NativeInit {
        kind: "minlstm".to_string(),
        n_layers: 2,
        d_model: 128,
        vocab_in: Some(vocab),
        vocab_out: vocab,
        conv: true,
        mlp: true,
        mlp_mult: 2,
        forget_bias: 1.0,
        ..Default::default()
    }, seed).unwrap();
    let (b, t) = (2usize, 64usize);
    let mut rng = Rng::new(seed ^ 0xD0);
    let x: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32)
        .collect();
    let targets: Vec<i32> = (0..b * t)
        .map(|_| rng.below(vocab as u64) as i32).collect();
    let mask = vec![1.0f32; b * t];
    (model, Tensor::i32(vec![b, t], x), targets, mask)
}

fn grads_for(model: &NativeModel, x: &Tensor, targets: &[i32],
             mask: &[f32], rate: f32, seed: i32) -> NativeModel {
    let (b, t) = (x.dims[0], x.dims[1]);
    let tape = autograd::forward_train(model, x, rate, seed).unwrap();
    let mut dlogits = Vec::new();
    loss::masked_ce(&tape.logits, targets, mask, b, t, model.vocab_out,
                    Some(&mut dlogits)).unwrap();
    let mut grads = model.zeros_like();
    autograd::backward(model, &tape, x, &dlogits, &mut grads).unwrap();
    grads
}

#[test]
fn drop_rate_zero_is_bit_identical_to_pre_dropout_path() {
    // training at rate 0 must produce the exact tape and gradients of the
    // dropout-free recording forward, whatever the seed
    let (model, x, targets, mask) = dropout_prop_model(31);
    let plain_tape = autograd::forward(&model, &x).unwrap();
    let train_tape = autograd::forward_train(&model, &x, 0.0, 0x1234)
        .unwrap();
    assert_eq!(plain_tape.logits, train_tape.logits);
    let g0 = grads_for(&model, &x, &targets, &mask, 0.0, 0x1234);
    let g1 = grads_for(&model, &x, &targets, &mask, 0.0, 0);
    for ((a, b), name) in g0.leaves().iter().zip(g1.leaves())
        .zip(g0.leaf_names()) {
        assert_eq!(*a, b, "rate=0 leaf '{name}' depends on drop_seed");
    }
}

#[test]
fn dropout_grads_are_thread_count_invariant_and_seed_deterministic() {
    // fixed drop_seed ⇒ identical masks, hence bit-identical grads, on 1
    // or N threads (the pool is process-global shared state: emulate via
    // set_active like the autograd tests)
    let (model, x, targets, mask) = dropout_prop_model(32);
    let pool = threads::global();
    let before = pool.active();
    let mut by_threads = Vec::new();
    for n in [1usize, 2, 7] {
        pool.set_active(n);
        by_threads.push(grads_for(&model, &x, &targets, &mask, 0.4, 99));
    }
    pool.set_active(before);
    let names = by_threads[0].leaf_names();
    for other in &by_threads[1..] {
        for ((a, b), name) in by_threads[0].leaves().iter()
            .zip(other.leaves()).zip(&names) {
            assert_eq!(*a, b,
                       "dropout leaf '{name}' differs across thread \
                        counts");
        }
    }
    // same seed twice: identical; different seed: different gradients
    let again = grads_for(&model, &x, &targets, &mask, 0.4, 99);
    for (a, b) in by_threads[0].leaves().iter().zip(again.leaves()) {
        assert_eq!(*a, b);
    }
    let other = grads_for(&model, &x, &targets, &mask, 0.4, 100);
    let differs = by_threads[0].leaves().iter().zip(other.leaves())
        .any(|(a, b)| *a != b);
    assert!(differs, "changing drop_seed must change dropout gradients");
}

// ---------------------------------------------------------------------------
// end-to-end: native train → checkpoint → native serve, per head
// ---------------------------------------------------------------------------

fn echo_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Batch {
    let x: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32)
        .collect();
    Batch {
        targets: Tensor::i32(vec![b, t], x.clone()),
        x: Tensor::i32(vec![b, t], x),
        mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
    }
}

#[test]
fn native_train_then_serve_cuts_loss_2x() {
    let vocab = 12usize;
    let model = NativeModel::init_random(&NativeInit {
        kind: "minlstm".to_string(),
        d_model: 16,
        n_layers: 1,
        vocab_in: Some(vocab),
        vocab_out: vocab,
        forget_bias: 1.0,
        ..Default::default()
    }, 21).unwrap();
    let mut trainer = NativeTrainer::new(model, "e2e-echo");
    let dir = std::env::temp_dir().join("minrnn_train_props_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TrainConfig {
        steps: 80,
        lr: 5e-3,
        schedule: Schedule::Constant,
        seed: 5,
        eval_every: 40,
        eval_batches: 2,
        log_every: 1000, // keep test output quiet
        checkpoint: Some(dir.clone()),
        ..Default::default()
    };
    let mut data = FnSource {
        f: move |rng: &mut Rng| echo_batch(rng, 8, 12, vocab),
    };
    let report = run_loop(&mut trainer, &cfg, 0, &mut data).unwrap();
    let (first_step, first_loss) = report.loss_curve[0];
    assert_eq!(first_step, 0);
    // the paper-level acceptance bar: >= 2x loss reduction from init
    assert!(report.final_loss < first_loss / 2.0,
            "loss {} -> {} is not a 2x drop", first_loss,
            report.final_loss);
    let eval = report.final_eval.expect("eval ran");
    assert!(eval.token_acc > 0.5,
            "echo task should be mostly learned, token_acc {}",
            eval.token_acc);

    // round-trip the best checkpoint into native inference and serve
    let ckpt = dir.join("e2e-echo.best.ckpt");
    assert!(ckpt.exists(), "best checkpoint written");
    let backend = NativeBackend::from_checkpoint(&ckpt).unwrap();
    let mut rng = Rng::new(0);
    let out = infer::generate(&backend, &[1, 2, 3], 8, 0.0, &mut rng)
        .unwrap();
    assert_eq!(out.len(), 8);
    // a well-trained echo model greedily repeats its last input token
    assert!(out.iter().all(|&tok| (0..vocab as i32).contains(&tok)));
    let stats = server::serve(&backend, (0..4).map(|i| server::Request {
        id: i,
        prompt: vec![(i % vocab as u64) as i32 + 1, 2],
        n_tokens: 4,
        session: None,
    }).collect(), 0.5, 1).unwrap();
    assert_eq!(stats.responses.len(), 4);
    assert!(stats.responses.iter().all(|r| r.tokens.len() == 4));

    // the final checkpoint also restores a resumable trainer
    let resumed = NativeTrainer::from_checkpoint(
        &dir.join("e2e-echo.final.ckpt"), "e2e-echo").unwrap();
    assert_eq!(resumed.step(), report.steps_run as u64);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rl_regression_trains_checkpoints_and_rolls_out() {
    // masked_mse e2e on a real offline-RL dataset: train the DT-style
    // regressor, checkpoint, reload through native inference, and roll
    // the policy out in the live environment.  Medium-Expert data: half
    // the actions are near-deterministic functions of the observation, so
    // the regression loss has substantial learnable structure.
    let ds = OfflineDataset::build("pointmass", Regime::MediumExpert, 24, 7);
    let f = ds.feature_dim();
    let model = NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        d_model: 24,
        n_layers: 2,
        vocab_in: None,
        input_dim: Some(f),
        vocab_out: ds.act_dim,
        mlp: true,
        ..Default::default()
    }, 40).unwrap();
    let mut trainer = NativeTrainer::new(model, "e2e-rl");
    trainer.head = Head::MaskedMse;
    let dir = std::env::temp_dir().join("minrnn_train_props_rl");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TrainConfig {
        steps: 200,
        lr: 3e-3,
        schedule: Schedule::Constant,
        seed: 9,
        eval_every: 100,
        eval_batches: 2,
        log_every: 1000,
        checkpoint: Some(dir.clone()),
        ..Default::default()
    };
    let (b, ctx) = (16usize, 12usize);
    let mut data = FnSource {
        f: move |rng: &mut Rng| ds.batch(rng, b, ctx),
    };
    let report = run_loop(&mut trainer, &cfg, 0, &mut data).unwrap();
    let (_, first_loss) = report.loss_curve[0];
    assert!(report.final_loss.is_finite());
    assert!(report.final_loss < 0.75 * first_loss,
            "mse loss {} -> {} did not drop 25%", first_loss,
            report.final_loss);

    // the checkpoint serves as a policy through native inference
    let ckpt = dir.join("e2e-rl.final.ckpt");
    let backend = NativeBackend::from_checkpoint(&ckpt).unwrap();
    let ds2 = OfflineDataset::build("pointmass", Regime::MediumExpert, 24,
                                    7);
    let ret = infer::rollout_decision(&backend, &ds2, ds2.target_return(),
                                      3).unwrap();
    assert!(ret.is_finite(), "rollout return {ret}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Synthetic classification rule over the LRA token map: label ∈ [0, 4)
/// is the (repeated) content token, filling the sequence right up to the
/// CLS read-out slot — learnable in CI time without long-range memory,
/// which is not what this e2e is testing.
fn cls_sample(rng: &mut Rng, t: usize) -> (Vec<i32>, i32) {
    let label = rng.below(4) as i32;
    (vec![label + 2; t - 1], label)
}

#[test]
fn lra_classification_trains_checkpoints_and_serves() {
    // seq_ce e2e through the LRA collate: the repeated-token rule stands
    // in for a real LRA task (learnable in CI time); the trained
    // checkpoint must classify through native prefill
    let (vocab, classes) = (8usize, 4usize);
    let t = 12usize;
    let model = NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        d_model: 24,
        n_layers: 1,
        vocab_in: Some(vocab),
        vocab_out: classes,
        ..Default::default()
    }, 50).unwrap();
    let mut trainer = NativeTrainer::new(model, "e2e-cls");
    trainer.head = Head::SeqClassify;
    let dir = std::env::temp_dir().join("minrnn_train_props_cls");
    std::fs::create_dir_all(&dir).unwrap();
    let mut data = FnSource {
        f: move |rng: &mut Rng| {
            let examples: Vec<(Vec<i32>, i32)> =
                (0..16).map(|_| cls_sample(rng, t)).collect();
            lra::collate_classification(&examples, t)
        },
    };
    let cfg = TrainConfig {
        steps: 150,
        lr: 5e-3,
        schedule: Schedule::Constant,
        seed: 11,
        eval_every: 75,
        eval_batches: 2,
        log_every: 1000,
        checkpoint: Some(dir.clone()),
        ..Default::default()
    };
    let report = run_loop(&mut trainer, &cfg, 0, &mut data).unwrap();
    let (_, first_loss) = report.loss_curve[0];
    assert!(report.final_loss < first_loss / 2.0,
            "cls loss {} -> {} is not a 2x drop", first_loss,
            report.final_loss);
    let eval = report.final_eval.expect("eval ran");
    assert!(eval.seq_acc > 0.5, "classification acc {}", eval.seq_acc);

    // checkpoint → native inference → prefill classifies fresh examples
    let backend = NativeBackend::from_checkpoint(
        &dir.join("e2e-cls.final.ckpt")).unwrap();
    let mut rng = Rng::new(77);
    let mut correct = 0usize;
    let n = 32usize;
    for _ in 0..n {
        let mut gen = Rng::new(rng.next_u64());
        let (tokens, label) = cls_sample(&mut gen, t);
        let batch = lra::collate_classification(&[(tokens, label)], t);
        let (logits, _) = backend.model.prefill(&batch.x).unwrap();
        let row = logits.data.as_f32().unwrap();
        let pred = (0..classes).max_by(|&a, &b| {
            row[a].partial_cmp(&row[b]).unwrap()
        }).unwrap();
        correct += usize::from(pred == label as usize);
    }
    assert!(correct as f64 / n as f64 > 0.5,
            "served classification accuracy {correct}/{n}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trained_checkpoint_is_pjrt_shaped() {
    // the checkpoint a native training run writes uses the same params/
    // leaf naming the AOT manifest path uses, so it loads back through
    // from_named without translation — and CONV_K pins the conv layout
    let model = NativeModel::init_random(&NativeInit {
        conv: true,
        mlp: true,
        vocab_in: Some(8),
        vocab_out: 8,
        d_model: 8,
        n_layers: 1,
        ..Default::default()
    }, 3).unwrap();
    let trainer = NativeTrainer::new(model, "shape");
    let named = trainer.model.to_named();
    let names: Vec<&str> = named.iter().map(|t| t.name.as_str()).collect();
    assert!(names.contains(&"params/blocks/0/mixer/linear_z/w"));
    assert!(names.contains(&"params/blocks/0/conv/w"));
    let conv = named.iter()
        .find(|t| t.name == "params/blocks/0/conv/w").unwrap();
    assert_eq!(conv.dims, vec![CONV_K, 8]);
    let back = NativeModel::from_named(&named).unwrap();
    assert_eq!(back.leaf_names(), trainer.model.leaf_names());
}
