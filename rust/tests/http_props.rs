//! Properties of the network serving tier (`coordinator::{http, shard}`):
//!
//! * **consistent-hash remap locality** — growing the replica set from N
//!   to N+1 moves only the keys the new member's ring segments claim
//!   (every moved key routes to the *added* member, and the moved share
//!   is bounded near 1/(N+1)); removing a member leaves every other
//!   member's keys exactly where they were;
//! * **loopback bit-identity** — greedy responses fetched over a real
//!   TCP socket (`POST /v1/submit`) match an in-process
//!   `Scheduler`/`SubmitHandle` run on an identically-seeded model
//!   token-for-token, and the error surface maps onto status codes
//!   (empty prompt → 400, unknown endpoint → 404, wrong method → 405);
//! * **hot-swap under traffic** — `POST /v1/reload` rolls a new
//!   checkpoint across the replicas while client threads keep
//!   submitting: every request gets a 200, post-swap output is
//!   bit-identical to the new checkpoint served in-process, a reload of
//!   a garbage path fails with a 5xx while the old model keeps serving,
//!   and the final drained stats satisfy
//!   `submitted == responses + expired + failed` with zero failures.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
use minrnn::coordinator::http::HttpServer;
use minrnn::coordinator::scheduler::Scheduler;
use minrnn::coordinator::server::{Request, ServeConfig};
use minrnn::coordinator::shard::{HashRing, ModelSource, Shard,
                                 DEFAULT_VNODES};
use minrnn::util::io;
use minrnn::util::json::{self, Json};

const VOCAB: usize = 16;
const KEYS: u64 = 2000;

fn tiny_init() -> NativeInit {
    NativeInit {
        vocab_in: Some(VOCAB),
        vocab_out: VOCAB,
        d_model: 16,
        n_layers: 1,
        ..Default::default()
    }
}

fn greedy_cfg() -> ServeConfig {
    ServeConfig::new().temperature(0.0).seed(7).max_batch(4)
        .build().unwrap()
}

/// Deterministic per-index prompt (no RNG: the HTTP and in-process runs
/// must build the exact same requests).
fn prompt_for(i: usize) -> Vec<i32> {
    (0..6).map(|k| (1 + (i * 5 + k * 3) % (VOCAB - 1)) as i32).collect()
}

// ---------------------------------------------------------------------------
// raw HTTP/1.1 client, hand-rolled like the server
// ---------------------------------------------------------------------------

/// One request/response round-trip.  Returns `(status, parsed body)`.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
        -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
            Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
           body.len()).unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let status: u16 = raw.split_whitespace().nth(1)
        .unwrap_or_else(|| panic!("malformed response: {raw:?}"))
        .parse().unwrap();
    let (_, payload) = raw.split_once("\r\n\r\n")
        .unwrap_or_else(|| panic!("no body in response: {raw:?}"));
    (status, json::parse(payload).unwrap())
}

fn submit(addr: SocketAddr, prompt: &[i32], n_tokens: usize,
          session: Option<u64>) -> (u16, Json) {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let sess = match session {
        Some(s) => format!(", \"session\": {s}"),
        None => String::new(),
    };
    let body = format!("{{\"prompt\": [{}], \"n_tokens\": {n_tokens}{sess}}}",
                       toks.join(", "));
    http(addr, "POST", "/v1/submit", &body)
}

fn tokens_of(v: &Json) -> Vec<i32> {
    v.req("tokens").unwrap().as_arr().unwrap().iter()
        .map(|t| t.as_i64().unwrap() as i32).collect()
}

// ---------------------------------------------------------------------------
// hash-ring remap locality
// ---------------------------------------------------------------------------

#[test]
fn adding_a_replica_remaps_only_onto_the_new_member() {
    for n in 1..=5usize {
        let before = HashRing::for_replicas(n, DEFAULT_VNODES);
        let after = HashRing::for_replicas(n + 1, DEFAULT_VNODES);
        let mut moved = 0usize;
        for key in 0..KEYS {
            let (old, new) = (before.route(key), after.route(key));
            if old != new {
                assert_eq!(new, n,
                           "key {key} moved {old} -> {new}, but only the \
                            added member {n} may claim moved keys");
                moved += 1;
            }
        }
        // the new member claims ~1/(n+1) of the key space — nonzero, and
        // nowhere near a full reshuffle (key % n would move ~n/(n+1))
        assert!(moved > 0, "n={n}: adding a member must claim some keys");
        let expect = KEYS as usize / (n + 1);
        assert!(moved < expect * 2 + 50,
                "n={n}: moved {moved} keys, expected about {expect}");
    }
}

#[test]
fn removing_a_replica_leaves_other_members_keys_in_place() {
    let n = 4usize;
    let full = HashRing::for_replicas(n, DEFAULT_VNODES);
    for dead in 0..n {
        let members: Vec<usize> = (0..n).filter(|&m| m != dead).collect();
        let reduced = HashRing::new(&members, DEFAULT_VNODES);
        let mut orphans = 0usize;
        for key in 0..KEYS {
            let old = full.route(key);
            let new = reduced.route(key);
            if old == dead {
                assert_ne!(new, dead, "key {key} routed to a dead member");
                orphans += 1;
            } else {
                // the survivors' ring points did not move: their
                // sessions keep their replica (and its cached state)
                assert_eq!(new, old,
                           "key {key} moved {old} -> {new} though only \
                            member {dead} was removed");
            }
        }
        assert!(orphans > 0, "member {dead} owned no keys at all");
    }
}

// ---------------------------------------------------------------------------
// loopback e2e: HTTP == in-process, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn http_greedy_responses_match_in_process_submit_handle() {
    let init = tiny_init();
    let cfg = greedy_cfg();
    let n_requests = 6usize;
    let n_tokens = 4usize;

    // in-process reference: same seeded model, raw Scheduler/SubmitHandle
    let backend =
        NativeBackend::new(NativeModel::init_random(&init, 11).unwrap());
    let (sched, handle) =
        Scheduler::new(&backend, cfg.scheduler_opts()).unwrap();
    for i in 0..n_requests {
        handle.submit(Request {
            id: i as u64,
            prompt: prompt_for(i),
            n_tokens,
            session: None,
        }).unwrap();
    }
    handle.close();
    let want = sched.run().unwrap();
    assert_eq!(want.responses.len(), n_requests);

    // network side: 2 replicas of the identically-seeded model
    let source = ModelSource::Fresh(init, 11);
    let shard = Shard::new(&source, &cfg, 2).unwrap();
    let server = HttpServer::bind("127.0.0.1:0", shard).unwrap();
    let addr = server.addr();

    for i in 0..n_requests {
        let (status, body) =
            submit(addr, &prompt_for(i), n_tokens, Some(i as u64));
        assert_eq!(status, 200, "submit {i} failed: {}",
                   json::to_string(&body));
        let got = tokens_of(&body);
        let reference = &want.responses.iter().find(|r| r.id == i as u64)
            .unwrap().tokens;
        assert_eq!(&got, reference,
                   "request {i}: greedy decode over HTTP must be \
                    bit-identical to the in-process scheduler");
    }

    // the error surface maps onto status codes
    let (status, body) = submit(addr, &[], 1, None);
    assert_eq!(status, 400);
    assert_eq!(body.req("kind").unwrap().as_str(), Some("empty_prompt"));
    let (status, _) = http(addr, "GET", "/v1/nope", "");
    assert_eq!(status, 404);
    let (status, _) = http(addr, "GET", "/v1/submit", "");
    assert_eq!(status, 405);

    // observability endpoints agree with what we just did
    let (status, health) = http(addr, "GET", "/v1/health", "");
    assert_eq!(status, 200);
    assert_eq!(health.req("health").unwrap().as_str(), Some("healthy"));
    assert_eq!(health.req("replicas").unwrap().as_usize(), Some(2));
    let (status, stats) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    assert_eq!(stats.req("responses").unwrap().as_usize(), Some(n_requests));

    server.stop();
    let drained = server.wait().unwrap();
    assert_eq!(drained.responses.len(), n_requests);
    assert_eq!(drained.submitted,
               drained.responses.len() + drained.expired.len()
                   + drained.failed.len(),
               "shutdown must account for every admitted request");
}

// ---------------------------------------------------------------------------
// checkpoint hot-swap under open-loop traffic
// ---------------------------------------------------------------------------

#[test]
fn hot_swap_mid_traffic_drops_nothing_and_switches_models() {
    let dir = std::env::temp_dir()
        .join(format!("minrnn_http_props_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let init = tiny_init();
    let save = |name: &str, seed: u64| -> PathBuf {
        let model = NativeModel::init_random(&init, seed).unwrap();
        let path = dir.join(name);
        io::save(&path, &model.to_named()).unwrap();
        path
    };
    let ckpt_a = save("a.ckpt", 11);
    let ckpt_b = save("b.ckpt", 99);

    let cfg = greedy_cfg();
    let source = ModelSource::Checkpoint(ckpt_a);
    let shard = Shard::new(&source, &cfg, 2).unwrap();
    let server = HttpServer::bind("127.0.0.1:0", shard).unwrap();
    let addr = server.addr();

    // open-loop traffic from 3 client threads, reload racing alongside
    let clients: Vec<_> = (0..3u64).map(|c| {
        std::thread::spawn(move || {
            let mut statuses = Vec::new();
            for i in 0..5usize {
                let (status, body) = submit(
                    addr, &prompt_for(i), 3, Some(c * 100 + i as u64));
                statuses.push((status, json::to_string(&body)));
            }
            statuses
        })
    }).collect();
    let body = format!("{{\"checkpoint\": {:?}}}", ckpt_b.to_str().unwrap());
    let (status, reply) = http(addr, "POST", "/v1/reload", &body);
    assert_eq!(status, 200, "reload failed: {}", json::to_string(&reply));
    assert_eq!(reply.req("reloaded").unwrap().as_usize(), Some(2));
    let mut submitted = 0usize;
    for c in clients {
        for (status, body) in c.join().unwrap() {
            assert_eq!(status, 200,
                       "a request was dropped during the rolling swap: \
                        {body}");
            submitted += 1;
        }
    }

    // after the swap, the shard serves checkpoint B bit-for-bit
    let backend_b = NativeBackend::from_checkpoint(&ckpt_b).unwrap();
    let want = cfg.run(&backend_b, vec![Request {
        id: 0, prompt: prompt_for(7), n_tokens: 4, session: None,
    }]).unwrap();
    let (status, body) = submit(addr, &prompt_for(7), 4, None);
    assert_eq!(status, 200);
    assert_eq!(tokens_of(&body), want.responses[0].tokens,
               "post-swap output must come from the new checkpoint");
    submitted += 1;

    // a garbage reload is a 5xx and leaves the (new) model serving
    let (status, reply) =
        http(addr, "POST", "/v1/reload",
             "{\"checkpoint\": \"/nonexistent/nope.ckpt\"}");
    assert_eq!(status, 500, "bogus checkpoint must not reload: {}",
               json::to_string(&reply));
    assert_eq!(reply.req("kind").unwrap().as_str(), Some("reload_failed"));
    let (status, body) = submit(addr, &prompt_for(8), 2, None);
    assert_eq!(status, 200);
    assert_eq!(tokens_of(&body).len(), 2);
    submitted += 1;

    // graceful drain over the wire, then the ledger must balance
    let (status, reply) = http(addr, "POST", "/v1/shutdown", "");
    assert_eq!(status, 200);
    assert_eq!(reply.req("draining").unwrap().as_bool(), Some(true));
    let stats = server.wait().unwrap();
    assert_eq!(stats.responses.len(), submitted,
               "every submitted request must have been answered");
    assert_eq!(stats.submitted,
               stats.responses.len() + stats.expired.len()
                   + stats.failed.len(),
               "hot-swap accounting must balance");
    assert!(stats.failed.is_empty(), "swap-attributable failures: {:?}",
            stats.failed);
    std::fs::remove_dir_all(&dir).ok();
}
