//! cargo-bench entry point regenerating paper experiment `tab2`
//! (see rust/src/bench_harness). Quick mode by default; MINRNN_FULL=1
//! for full scale. Requires `make artifacts`.

use std::path::Path;

use minrnn::bench_harness::Ctx;
use minrnn::coordinator::run_experiment;

fn main() {
    let ctx = Ctx::new(Path::new("artifacts")).expect("load artifacts");
    run_experiment(&ctx, "tab2").expect("experiment tab2");
}
