//! cargo-bench entry point for the native-backend throughput benchmark
//! (prefill / decode / serve tokens-per-second; see
//! `rust/src/bench_harness/native_throughput.rs`).  Needs **no
//! artifacts**.  Quick mode by default; MINRNN_FULL=1 for full scale.
//! Writes BENCH_native.json to the working directory; CI uploads it and
//! gates on regression against the committed baseline.

use minrnn::bench_harness::native_throughput::{run, Config};

fn main() {
    minrnn::util::logging::init();
    let full = std::env::var("MINRNN_FULL").ok().as_deref() == Some("1");
    let cfg = if full { Config::full() } else { Config::quick() };
    run(&cfg).expect("native throughput bench");
}
