//! Stub of the `xla` crate API surface used by minrnn.
//!
//! The real crate binds PJRT/XLA through a native toolchain that the
//! hermetic build environment cannot provide, but most of what minrnn
//! passes around are plain host literals (parameter leaves, batches,
//! checkpoints).  This stub therefore implements [`Literal`] as a real
//! host-side tensor container — construction, reshape, readback and tuple
//! decomposition all work — while [`PjRtClient::compile`] and
//! [`PjRtLoadedExecutable::execute`] return [`Error`] explaining that HLO
//! execution needs the real crate.  The native pure-Rust backend
//! (`minrnn::backend`) never hits those paths.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

pub const STUB_EXECUTE_MSG: &str =
    "the in-tree `xla` stub cannot compile or execute HLO; swap the `xla` \
     path dependency in rust/Cargo.toml for the real PJRT-capable crate to \
     use the artifact backend (the native backend needs no artifacts)";

#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Tuple,
}

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor literal (array or tuple), mirroring `xla::Literal`.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Element types a [`Literal`] can hold (mirrors the real crate's
/// `NativeType` bound on `vec1` / `to_vec` / `get_first_element`).
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn make_literal(v: &[Self]) -> Literal;
    fn read_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn make_literal(v: &[f32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: Data::F32(v.to_vec()) }
    }

    fn read_literal(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            other => Err(Error::new(format!(
                "literal is not f32 (got {})", data_kind(other)))),
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn make_literal(v: &[i32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: Data::I32(v.to_vec()) }
    }

    fn read_literal(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            other => Err(Error::new(format!(
                "literal is not i32 (got {})", data_kind(other)))),
        }
    }
}

fn data_kind(d: &Data) -> &'static str {
    match d {
        Data::F32(_) => "f32",
        Data::I32(_) => "i32",
        Data::Tuple(_) => "tuple",
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::make_literal(v)
    }

    pub fn tuple(leaves: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(leaves) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(leaves) => {
                leaves.iter().map(|l| l.element_count()).sum()
            }
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape to {:?} ({} elements) from {} elements",
                dims, n, self.element_count())));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => {
                return Err(Error::new("tuple literal has no array shape"));
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read_literal(self)?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(leaves) => Ok(leaves.clone()),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed-enough representation of an HLO text artifact.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::new(format!("read {}: {e}", path.display()))
        })?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error::new(format!(
                "{}: not HLO text (missing HloModule header)",
                path.display())));
        }
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_EXECUTE_MSG))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self, _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_EXECUTE_MSG))
    }
}

pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    /// Always succeeds: the host "client" exists, it just cannot compile.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "stub-cpu".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_EXECUTE_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1i32, 2]),
            Literal::vec1(&[0.5f32]),
        ]);
        let leaves = t.to_tuple().unwrap();
        assert_eq!(leaves.len(), 2);
        assert_eq!(leaves[0].to_vec::<i32>().unwrap(), vec![1, 2]);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_exists_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let dir = std::env::temp_dir().join("xla_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.hlo.txt");
        std::fs::write(&p, "HloModule m\n").unwrap();
        let proto = HloModuleProto::from_text_file(&p).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
