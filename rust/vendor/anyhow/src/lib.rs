//! Minimal, dependency-free reimplementation of the `anyhow` API surface
//! used by minrnn.  The hermetic build environment has no crates.io access,
//! so this vendored crate stands in for the real one; swap it out by
//! editing the `anyhow` path dependency in rust/Cargo.toml if the registry
//! is reachable.
//!
//! Provided: [`Error`] (context chain, `{e}` / `{e:#}` / `{e:?}` formats),
//! [`Result`], the [`Context`] extension trait for `Result` and `Option`,
//! and the `anyhow!`, `bail!`, `ensure!` macros.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Error with a chain of context messages: `chain[0]` is the outermost
/// context, the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Context messages from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into `Error`, capturing its source chain.
/// (Coherence works because `Error` itself does not implement `StdError`,
/// exactly like the real anyhow.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Internal conversion helper so `Context` can be implemented both for
/// `Result<T, E: StdError>` and `Result<T, Error>` without overlap
/// (the same trick the real anyhow uses in its `ext` module).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading config".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_compose() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()),
                   "failed with code 7");
        let e = anyhow!("plain {}", 3);
        assert_eq!(e.root_cause(), "plain 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
